"""Paged KV cache: engine parity, block pool, prefix reuse, COW, kernel.

The definitive guard for the paged tentpole: for ANY mix of prompt lengths,
`Engine(kv_layout="paged")` must generate token-for-token what the
contiguous-lane engine generates — on both decode loops — while routing
every KV byte through the global block pool and per-request block tables.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # fallback: deterministic samples, see _propstub
    from _propstub import given, settings, st

from repro.configs.registry import get_smoke_config
from repro.models import PagedKVCache, init_params
from repro.serve.engine import Engine, ServeConfig
from repro.serve.paged_cache import BlockPool, block_hashes
from repro.serve.scheduler import Scheduler


MAX_PROMPT = 8
BATCH = 3


def _tiny_cfg():
    return get_smoke_config("llama3_8b").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32", remat=False)


@pytest.fixture(scope="module")
def tiny():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def engines(tiny):
    cfg, params = tiny
    out = {}
    for loop in ("scan", "step"):
        out[loop] = {
            "contiguous": Engine(params, cfg,
                                 ServeConfig(max_len=32, decode_loop=loop)),
            "paged": Engine(params, cfg,
                            ServeConfig(max_len=32, decode_loop=loop,
                                        kv_layout="paged", block_size=8)),
        }
    return cfg, out


def _ragged_batch(cfg, seed: int):
    key = jax.random.PRNGKey(seed)
    lens = np.asarray(jax.random.randint(key, (BATCH,), 1, MAX_PROMPT + 1))
    padded = np.zeros((BATCH, MAX_PROMPT), np.int32)
    for i, L in enumerate(lens):
        padded[i, :int(L)] = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (int(L),), 0, cfg.vocab_size))
    return lens.astype(np.int32), padded


# ---------------------------------------------------------------------------
# Property: paged decoding ≡ contiguous decoding (the acceptance pin)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=1000))
def test_paged_matches_contiguous_on_ragged_batches(engines, seed):
    cfg, engs = engines
    lens, padded = _ragged_batch(cfg, seed)
    for loop in ("scan", "step"):
        cont = np.asarray(engs[loop]["contiguous"].generate(
            jnp.asarray(padded), 6, prompt_lens=lens))
        paged = np.asarray(engs[loop]["paged"].generate(
            jnp.asarray(padded), 6, prompt_lens=lens))
        assert np.array_equal(cont, paged), (loop, seed, lens)


def test_paged_matches_contiguous_uniform(engines):
    """No prompt_lens (the legacy uniform path) is paged-equal too."""
    cfg, engs = engines
    prompts = jax.random.randint(jax.random.PRNGKey(11), (BATCH, 5), 0,
                                 cfg.vocab_size)
    for loop in ("scan", "step"):
        a = np.asarray(engs[loop]["contiguous"].generate(prompts, 6))
        b = np.asarray(engs[loop]["paged"].generate(prompts, 6))
        assert np.array_equal(a, b), loop


def test_paged_eos_masked_continuation(tiny):
    cfg, params = tiny
    lens, padded = _ragged_batch(cfg, seed=5)
    free = np.asarray(Engine(params, cfg, ServeConfig(max_len=32)).generate(
        jnp.asarray(padded), 8, prompt_lens=lens))
    eos = int(free[0, 3])
    for loop in ("scan", "step"):
        cont = Engine(params, cfg, ServeConfig(max_len=32, eos_id=eos,
                                               decode_loop=loop))
        paged = Engine(params, cfg, ServeConfig(max_len=32, eos_id=eos,
                                                decode_loop=loop,
                                                kv_layout="paged",
                                                block_size=8))
        a = np.asarray(cont.generate(jnp.asarray(padded), 8,
                                     prompt_lens=lens))
        b = np.asarray(paged.generate(jnp.asarray(padded), 8,
                                      prompt_lens=lens))
        assert np.array_equal(a, b), loop


def test_paged_serve_config_validation():
    with pytest.raises(ValueError, match="kv_layout"):
        ServeConfig(kv_layout="bogus")
    with pytest.raises(ValueError, match="multiple of"):
        ServeConfig(kv_layout="paged", max_len=60, block_size=16)
    with pytest.raises(ValueError, match="drained pool"):
        ServeConfig(kv_layout="paged", max_len=64, block_size=16,
                    num_blocks=2)
    scfg = ServeConfig(kv_layout="paged", max_len=64, block_size=16,
                       batch_slots=4)
    assert scfg.blocks_per_seq == 4 and scfg.pool_blocks == 16


def test_paged_rejects_stateful_families():
    ssm_cfg = get_smoke_config("mamba2_780m").reduced(d_model=32, n_layers=2)
    ssm_params = init_params(jax.random.PRNGKey(0), ssm_cfg)
    eng = Engine(ssm_params, ssm_cfg,
                 ServeConfig(max_len=32, kv_layout="paged", block_size=8))
    with pytest.raises(NotImplementedError, match="family"):
        eng.generate(jnp.zeros((2, 4), jnp.int32), 2)


# ---------------------------------------------------------------------------
# BlockPool: refcounts, eviction, chained prefix index, copy-on-write
# ---------------------------------------------------------------------------

def test_block_hashes_chain():
    toks = np.arange(20, dtype=np.int32)
    h = block_hashes(toks, 8)
    assert len(h) == 2                        # only full blocks
    # chained: same second block behind a different first block ≠ match
    other = toks.copy()
    other[0] += 1
    assert block_hashes(other, 8)[1] != h[1]
    assert block_hashes(toks[:16], 8) == h


def test_pool_alloc_free_refcount():
    pool = BlockPool(4, 8)
    a = pool.alloc(3)
    assert sorted(a) == [0, 1, 2] and pool.available() == 1
    assert pool.alloc(2) is None              # atomic: all or none
    pool.incref([a[0]])
    pool.free(a)
    assert pool.available() == 3              # a[0] still held once
    pool.free([a[0]])
    assert pool.available() == 4 and pool.live() == 0
    with pytest.raises(ValueError, match="double free"):
        pool.free([a[0]])


def test_pool_prefix_match_and_eviction():
    pool = BlockPool(4, 4)
    toks = np.arange(12, dtype=np.int32)      # 3 full blocks
    blocks = pool.alloc(3)
    pool.register_prefix(toks, blocks)
    # a full-prompt match takes a ref on every block
    ids, n = pool.match_prefix(toks)
    assert ids == blocks and n == 12
    pool.free(ids)
    pool.free(blocks)                         # owner retires
    assert pool.available() == 4 and pool.cached == 3
    # matching a shorter prefix only takes the matching chain
    ids, n = pool.match_prefix(np.concatenate([toks[:8], [99, 98]]))
    assert ids == blocks[:2] and n == 8
    pool.free(ids)
    # exhaustion evicts cached blocks LRU and drops their index entries
    got = pool.alloc(4)
    assert got is not None and pool.evictions == 3
    ids, n = pool.match_prefix(toks)
    assert ids == [] and n == 0


def test_pool_cow_semantics():
    pool = BlockPool(4, 4)
    toks = np.arange(4, dtype=np.int32)
    (b0,) = pool.alloc(1)
    # private, unindexed block: write in place
    assert pool.cow(b0) == b0
    pool.register_prefix(toks, [b0])
    # indexed block: must copy even with one holder (the cache entry would
    # silently diverge otherwise)
    dst = pool.cow(b0)
    assert dst != b0 and pool.ref[dst] == 1
    pool.free([dst])
    # shared block (second holder via match): must copy
    pool.free([b0])
    ids, _ = pool.match_prefix(toks)
    assert ids == [b0]
    dst = pool.cow(b0)
    assert dst is not None and dst != b0


# ---------------------------------------------------------------------------
# Pallas paged-gather kernel ≈ gathered reference
# ---------------------------------------------------------------------------

def test_paged_kernel_matches_gather_reference():
    from repro.kernels.paged_attention import paged_decode_attention
    rng = np.random.default_rng(0)
    b, hq, hkv, hd, bs, n_total, nbr = 3, 4, 2, 32, 8, 12, 3
    q = jnp.asarray(rng.normal(size=(b, 1, hq, hd)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(n_total, bs, hkv, hd))
                     .astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(n_total, bs, hkv, hd))
                     .astype(np.float32))
    bt = jnp.asarray(np.array([[0, 3, 7], [2, 5, n_total],
                               [9, n_total, n_total]], np.int32))
    klen = jnp.asarray(np.array([20, 11, 4], np.int32))
    out = np.asarray(paged_decode_attention(q, kp, vp, bt, klen,
                                            interpret=True))

    kf = np.asarray(kp).reshape(n_total * bs, hkv, hd)
    vf = np.asarray(vp).reshape(n_total * bs, hkv, hd)
    group = hq // hkv
    for i in range(b):
        idx = (np.clip(np.asarray(bt)[i], 0, n_total - 1)[:, None] * bs
               + np.arange(bs)).reshape(-1)
        for h in range(hq):
            kh, vh = kf[idx][:, h // group], vf[idx][:, h // group]
            s = (np.asarray(q)[i, 0, h] @ kh.T) * hd ** -0.5
            s[np.arange(len(s)) >= int(klen[i])] = -1e30
            p = np.exp(s - s.max())
            p /= p.sum()
            np.testing.assert_allclose(out[i, 0, h], p @ vh,
                                       rtol=1e-5, atol=1e-5)


def test_paged_engine_with_pallas_kernel(tiny):
    """Full paged generation routed through the Pallas decode kernel
    (interpret mode) stays close to the XLA gather path."""
    from repro.runtime import RuntimeConfig
    cfg, params = tiny
    lens, padded = _ragged_batch(cfg, seed=3)
    mk = lambda rt: Engine(params, cfg,
                           ServeConfig(max_len=32, kv_layout="paged",
                                       block_size=8), rt=rt)
    xla = np.asarray(mk(RuntimeConfig(use_pallas=False)).generate(
        jnp.asarray(padded), 5, prompt_lens=lens))
    pls = np.asarray(mk(RuntimeConfig(use_pallas=True, interpret=True))
                     .generate(jnp.asarray(padded), 5, prompt_lens=lens))
    # greedy argmax over f32 logits: reduction-order differences between the
    # kernel and the gather path may flip near-ties on a handful of steps,
    # but the overwhelming majority must agree
    assert (xla == pls).mean() > 0.8


def test_tuning_routes_paged_kernel():
    from repro.kernels import tuning
    assert tuning.use_paged_kernel(8, 32, 16, 8, 128)
    # a pathological block shape must fall back to the gather path
    assert not tuning.use_paged_kernel(8, 4, 65536, 8, 4096)


# ---------------------------------------------------------------------------
# Paged scheduler: parity, prefix reuse, COW, preemption
# ---------------------------------------------------------------------------

def _prompts(cfg, spec, seed=2):
    key = jax.random.PRNGKey(seed)
    return [(np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                           (L,), 0, cfg.vocab_size)), n)
            for i, (L, n) in enumerate(spec)]


def test_paged_scheduler_matches_per_request_generate(tiny):
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2,
                                          kv_layout="paged", block_size=8))
    sched = Scheduler(eng, chunk_size=3)
    reqs = [(p, n, sched.submit(p, n)) for p, n in
            _prompts(cfg, [(5, 8), (2, 4), (7, 11), (3, 1), (4, 6), (6, 9)])]
    sched.run()
    for prompt, n, handle in reqs:
        ref = np.asarray(eng.generate(jnp.asarray(prompt[None]), n))[0]
        assert np.array_equal(np.asarray(handle.tokens), ref), \
            (len(prompt), n)
    assert sched.pool.live() == 0             # every page returned


def test_prefix_reuse_hits_and_matches(tiny):
    """Requests sharing a prompt prefix map to the same physical pages,
    skip re-prefilling them, and still generate identical tokens."""
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2,
                                          kv_layout="paged", block_size=8))
    shared = np.asarray(jax.random.randint(jax.random.PRNGKey(9), (20,), 0,
                                           cfg.vocab_size))
    reqs = [(np.concatenate([shared, np.asarray(t, np.int32)]), n)
            for t, n in ([[3, 5], 6], [[7], 5], [[1, 2, 3], 4])]
    sched = Scheduler(eng, chunk_size=4)
    handles = [(p, n, sched.submit(p, n)) for p, n in reqs]
    sched.run()
    for p, n, h in handles:
        ref = np.asarray(eng.generate(jnp.asarray(p[None]), n))[0]
        assert np.array_equal(np.asarray(h.tokens), ref)
    assert sched.prefix_hits == 2             # 2nd and 3rd share 2 pages
    assert sched.shared_tokens == 2 * 16
    assert 0 < sched.prefix_hit_rate < 1


def test_full_prompt_cache_hit_triggers_cow(tiny):
    """An identical block-aligned prompt re-submitted after retirement hits
    every page; the last one is copy-on-written before the logits
    re-prefill, and the generation still matches a fresh run."""
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2,
                                          kv_layout="paged", block_size=8))
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(4), (24,), 0,
                                           cfg.vocab_size))
    sched = Scheduler(eng, chunk_size=4)
    h1 = sched.submit(prompt, 4)
    sched.run()
    h2 = sched.submit(prompt, 6)
    sched.run()
    assert sched.cow_copies == 1
    assert sched.shared_tokens >= 23          # everything but the last token
    ref = np.asarray(eng.generate(jnp.asarray(prompt[None]), 6))[0]
    assert np.array_equal(np.asarray(h2.tokens), ref)
    assert np.array_equal(np.asarray(h1.tokens), ref[:4])


def test_prefix_reuse_off_never_shares(tiny):
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2,
                                          kv_layout="paged", block_size=8))
    p = np.asarray(jax.random.randint(jax.random.PRNGKey(8), (16,), 0,
                                      cfg.vocab_size))
    sched = Scheduler(eng, chunk_size=4, prefix_reuse=False)
    h1, h2 = sched.submit(p, 4), sched.submit(p, 4)
    sched.run()
    assert sched.shared_tokens == 0 and sched.prefix_hit_rate == 0.0
    assert h1.tokens == h2.tokens


def test_preemption_under_tiny_pool_still_exact(tiny):
    """A pool of exactly one max-length lane forces preempt-to-queue; the
    preempted request resumes by re-prefilling its own generation and
    still matches its dedicated run token-for-token."""
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2,
                                          kv_layout="paged", block_size=8,
                                          num_blocks=8))
    sched = Scheduler(eng, chunk_size=4)
    reqs = [(p, n, sched.submit(p, n)) for p, n in
            _prompts(cfg, [(20, 30), (16, 40), (10, 20)], seed=5)]
    sched.run()
    assert sched.preemptions > 0
    for p, n, h in reqs:
        ref = np.asarray(eng.generate(jnp.asarray(p[None]), n))[0]
        assert np.array_equal(np.asarray(h.tokens), ref), (len(p), n)
    assert sched.pool.live() == 0


# ---------------------------------------------------------------------------
# Sharding: the block pool has no batch axis to shard
# ---------------------------------------------------------------------------

def test_paged_pool_spec_shards_heads_not_blocks():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import paged_pool_spec
    sizes = {"data": 2, "model": 2}
    # [num_blocks, block_size, n_kv, hd]: model → kv heads, blocks unsharded
    assert paged_pool_spec("/g/0/k", (64, 16, 4, 128), sizes) == \
        P(None, None, "model", None)
    # few-kv-head: fall through to head_dim
    assert paged_pool_spec("/g/0/k", (64, 16, 1, 128), sizes) == \
        P(None, None, None, "model")
    # seq_to_data pages across data replicas
    assert paged_pool_spec("/g/0/v", (64, 16, 4, 128), sizes,
                           seq_to_data=True) == \
        P("data", None, "model", None)
    # scalars / non-kv leaves replicated
    assert paged_pool_spec("/g/0/length", (), sizes) == P()


def test_cache_shardings_handles_paged_tree(tiny):
    from repro.models import init_paged_caches
    from repro.sharding.rules import cache_shardings
    cfg, _ = tiny
    caches = init_paged_caches(cfg, 16, 8)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1),
                             ("model",))
    sds = cache_shardings(caches, mesh)
    leaves = jax.tree.leaves(sds, is_leaf=lambda x: isinstance(
        x, jax.sharding.NamedSharding))
    assert leaves and all(isinstance(s, jax.sharding.NamedSharding)
                          for s in leaves)
