"""Multi-tenant adapter serving: registry folding, device pools, routed
scheduler parity against merged-weight references, prefix isolation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models import init_params
from repro.quant import calibrate, quantize_model, reduce_shared, registry
from repro.serve.adapters import (BASE_SLOT, AdapterPool, AdapterRegistry,
                                  adapter_slot_count, install_pools,
                                  iter_quant_leaves, load_adapter,
                                  padded_rank)
from repro.serve.engine import Engine, ServeConfig
from repro.serve.lifecycle import assert_drained
from repro.serve.scheduler import Scheduler


def _tiny_cfg():
    return get_smoke_config("llama3_8b").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32", remat=False)


@pytest.fixture(scope="module")
def tiny_quant():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tape = reduce_shared(
        calibrate(params, cfg, corpus.calibration_batches(2, 4, 16)), cfg)
    return cfg, quantize_model(params, tape, "aser_as(rank=8)")


def _prompts(cfg, spec, seed=2):
    key = jax.random.PRNGKey(seed)
    return [(np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                           (L,), 0, cfg.vocab_size)), n)
            for i, (L, n) in enumerate(spec)]


# ---------------------------------------------------------------------------
# Registry: folding correctness, validation, merged reference
# ---------------------------------------------------------------------------

def test_folded_factors_match_raw_epilogue(tiny_quant):
    """With x_s = x / m, the served (x_s @ a_s) @ b must equal the
    adapter's raw (x @ A) @ B on every target — smoothing folds into A."""
    cfg, qp = tiny_quant
    reg = AdapterRegistry(qp, rank=5)              # odd rank: pads to 8
    reg.add("t0")
    folded = reg.folded("t0")
    raw = reg._raw["t0"]
    leaves = dict(iter_quant_leaves(qp))
    assert set(folded) == set(leaves) and len(folded) > 0
    rng = np.random.default_rng(0)
    for path, (a_s, b) in folded.items():
        m = np.asarray(leaves[path]["m"], np.float32)
        a, braw = raw[path]
        assert a_s.shape[-1] == padded_rank(5) == 8
        x = rng.standard_normal(m.shape[:-1] + (3, m.shape[-1]))
        x = x.astype(np.float32)
        want = (x @ a) @ braw
        got = ((x / m[..., None, :]) @ np.asarray(a_s)) @ np.asarray(b)
        np.testing.assert_allclose(got, want, atol=1e-4), path


def test_registry_validation(tiny_quant):
    cfg, qp = tiny_quant
    reg = AdapterRegistry(qp, rank=4)
    reg.add("t0")
    with pytest.raises(ValueError, match="already registered"):
        reg.add("t0")
    with pytest.raises(KeyError, match="missing factors"):
        reg.add("partial", factors={})
    path, lead, k, n = (reg._targets[0][0], reg._targets[0][1],
                        reg._targets[0][2], reg._targets[0][3])
    bad = {p: (np.zeros(ld + (kk, 4), np.float32),
               np.zeros(ld + (4, nn), np.float32))
           for p, ld, kk, nn, _ in reg._targets}
    bad[path] = (np.zeros(lead + (k + 1, 4), np.float32), bad[path][1])
    with pytest.raises(ValueError, match="factor shapes"):
        reg.add("bad", factors=bad)
    # a pure-fp model has nothing to adapt
    cfg2 = _tiny_cfg()
    fp = init_params(jax.random.PRNGKey(1), cfg2)
    with pytest.raises(ValueError, match="quantized base"):
        AdapterRegistry(fp, rank=4)


def test_merged_params_extend_lowrank_and_drop_pools(tiny_quant):
    cfg, qp = tiny_quant
    reg = AdapterRegistry(qp, rank=4)
    reg.add("t0")
    pooled = install_pools(qp, slots=3, rank=4)
    merged = reg.merged_params(pooled, "t0")
    for (_, base), (_, m) in zip(iter_quant_leaves(qp),
                                 iter_quant_leaves(merged)):
        assert "alb" not in m and "ala" not in m
        assert m["lb"].shape[-1] == base["lb"].shape[-1] + reg.ra
        assert m["la"].shape[-2] == base["la"].shape[-2] + reg.ra


# ---------------------------------------------------------------------------
# Device pools: install/load shapes, pinned base slot
# ---------------------------------------------------------------------------

def test_install_and_load_pools(tiny_quant):
    cfg, qp = tiny_quant
    assert adapter_slot_count(qp) == 0
    reg = AdapterRegistry(qp, rank=4)
    reg.add("t0")
    pooled = install_pools(qp, slots=3, rank=4)
    assert adapter_slot_count(pooled) == 3
    for path, leaf in iter_quant_leaves(pooled):
        lead = leaf["qw"].shape[:-2]
        k, n = leaf["m"].shape[-1], leaf["sw"].shape[-1]
        assert leaf["alb"].shape == lead + (3, k, 8)
        assert leaf["ala"].shape == lead + (3, 8, n)
    loaded = load_adapter(pooled, reg.folded("t0"), 1)
    for (path, leaf), (_, src) in zip(iter_quant_leaves(loaded),
                                      iter_quant_leaves(pooled)):
        a_s, b = reg.folded("t0")[path]
        # slot 0 (base) and slot 2 stay all-zero; slot 1 holds the factors
        assert not np.asarray(leaf["alb"][..., BASE_SLOT, :, :]).any()
        assert not np.asarray(leaf["alb"][..., 2, :, :]).any()
        np.testing.assert_array_equal(leaf["alb"][..., 1, :, :], a_s)
        np.testing.assert_array_equal(leaf["ala"][..., 1, :, :], b)
    with pytest.raises(ValueError, match="base adapter"):
        load_adapter(pooled, reg.folded("t0"), BASE_SLOT)
    with pytest.raises(ValueError, match="slots >= 2"):
        install_pools(qp, slots=1, rank=4)
    assert reg.pool_bytes_per_adapter() == sum(
        int(np.prod(ld or (1,))) * (k + n) * 8 * 4
        for _, ld, k, n, _ in reg._targets)


# ---------------------------------------------------------------------------
# Routed serving ≡ merged-weight per-request generate (token-exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_layout", ["contiguous", "paged"])
@pytest.mark.parametrize("loop", ["scan", "step"])
def test_scheduler_adapter_parity(tiny_quant, kv_layout, loop):
    """Mixed adapter-tagged traffic through the continuous-batching
    scheduler equals each request's dedicated merged-weight generation,
    token for token — across both decode loops and both KV layouts. Base
    requests (no tag) route slot 0 and must match the unpooled model."""
    cfg, qp = tiny_quant
    reg = AdapterRegistry(qp, rank=4)
    tenants = [reg.add(f"t{i}") for i in range(2)]
    pooled = install_pools(qp, slots=3, rank=4)
    kw = dict(kv_layout=kv_layout, block_size=8) \
        if kv_layout == "paged" else {}
    eng = Engine(pooled, cfg, ServeConfig(max_len=64, batch_slots=2,
                                          decode_loop=loop, **kw))
    sched = Scheduler(eng, chunk_size=3, adapters=reg)
    tags = [None, tenants[0], tenants[1], tenants[0], None]
    reqs = [(p, n, aid, sched.submit(p, n, adapter_id=aid))
            for (p, n), aid in zip(
                _prompts(cfg, [(5, 8), (7, 6), (4, 9), (6, 5), (3, 7)]),
                tags)]
    sched.run()
    assert_drained(sched)
    for p, n, aid, h in reqs:
        assert h.done
        refp = qp if aid is None else reg.merged_params(qp, aid)
        ref_eng = Engine(refp, cfg, ServeConfig(max_len=64, batch_slots=1,
                                                decode_loop=loop))
        ref = np.asarray(ref_eng.generate(jnp.asarray(p[None]), n))[0]
        assert np.array_equal(np.asarray(h.tokens), ref), (aid, len(p), n)


def test_prefix_cache_isolated_across_adapters(tiny_quant):
    """Two tenants sharing a prompt must NOT share prefix pages (the KV
    content differs through the adapted layers); the same tenant repeating
    its prompt must hit. Both repeats stay token-exact."""
    cfg, qp = tiny_quant
    reg = AdapterRegistry(qp, rank=4)
    ta, tb = reg.add("a"), reg.add("b")
    pooled = install_pools(qp, slots=3, rank=4)
    eng = Engine(pooled, cfg, ServeConfig(max_len=64, batch_slots=2,
                                          kv_layout="paged", block_size=8))
    (p, n), = _prompts(cfg, [(17, 4)], seed=11)
    sched = Scheduler(eng, chunk_size=2, adapters=reg)
    h1 = sched.submit(p, n, adapter_id=ta)
    sched.run()
    assert sched.adapter_prefix_hit_rate(ta) == 0.0      # cold
    h2 = sched.submit(p, n, adapter_id=tb)               # other tenant
    sched.run()
    assert sched.adapter_prefix_hit_rate(tb) == 0.0, \
        "tenant b reused tenant a's KV pages"
    h3 = sched.submit(p, n, adapter_id=ta)               # same tenant again
    sched.run()
    assert sched.adapter_prefix_hit_rate(ta) > 0.0
    for aid, h in ((ta, h1), (tb, h2), (ta, h3)):
        ref_eng = Engine(reg.merged_params(qp, aid), cfg,
                         ServeConfig(max_len=64, batch_slots=1))
        ref = np.asarray(ref_eng.generate(jnp.asarray(p[None]), n))[0]
        assert np.array_equal(np.asarray(h.tokens), ref), aid


def test_pool_exhaustion_delays_admission(tiny_quant):
    """More live tenants than adapter slots: the scheduler must keep the
    extra request queued until a slot unpins, then serve it correctly."""
    cfg, qp = tiny_quant
    reg = AdapterRegistry(qp, rank=4)
    tenants = [reg.add(f"t{i}") for i in range(3)]
    pooled = install_pools(qp, slots=3, rank=4)   # only 2 adapter slots
    eng = Engine(pooled, cfg, ServeConfig(max_len=64, batch_slots=3))
    sched = Scheduler(eng, chunk_size=2, adapters=reg)
    reqs = [(p, n, aid, sched.submit(p, n, adapter_id=aid))
            for (p, n), aid in zip(
                _prompts(cfg, [(5, 10), (6, 10), (4, 6)], seed=5), tenants)]
    assert sched.step()
    # three batch slots but only two adapter slots: t2 must still be queued
    h2 = reqs[2][3]
    assert not h2.tokens and sched.pending == 3
    sched.run()
    for p, n, aid, h in reqs:
        ref_eng = Engine(reg.merged_params(qp, aid), cfg,
                         ServeConfig(max_len=64, batch_slots=1))
        ref = np.asarray(ref_eng.generate(jnp.asarray(p[None]), n))[0]
        assert h.done and np.array_equal(np.asarray(h.tokens), ref), aid
    assert sched.apool.evictions >= 1


def test_scheduler_adapter_validation(tiny_quant):
    cfg, qp = tiny_quant
    reg = AdapterRegistry(qp, rank=4)
    reg.add("t0")
    eng_plain = Engine(qp, cfg, ServeConfig(max_len=32, batch_slots=1))
    with pytest.raises(ValueError, match="install_pools"):
        Scheduler(eng_plain, adapters=reg)
    with pytest.raises(ValueError, match="adapter registry"):
        sched = Scheduler(eng_plain)
        sched.submit([1, 2, 3], 2, adapter_id="t0")
    pooled = install_pools(qp, slots=3, rank=4)
    eng = Engine(pooled, cfg, ServeConfig(max_len=32, batch_slots=1))
    sched = Scheduler(eng, adapters=reg)
    with pytest.raises(ValueError, match="unknown adapter"):
        sched.submit([1, 2, 3], 2, adapter_id="ghost")
    with pytest.raises(ValueError, match="adapter_pool"):
        Scheduler(eng, adapter_pool=AdapterPool(3))
    with pytest.raises(ValueError, match="slots"):
        Scheduler(eng, adapters=reg, adapter_pool=AdapterPool(5))


def test_shared_pool_keeps_adapters_warm(tiny_quant):
    """A pool handed across scheduler restarts skips reloading resident
    factors — the long-lived-process serving pattern the bench times."""
    cfg, qp = tiny_quant
    reg = AdapterRegistry(qp, rank=4)
    reg.add("t0")
    pooled = install_pools(qp, slots=3, rank=4)
    eng = Engine(pooled, cfg, ServeConfig(max_len=64, batch_slots=1))
    apool = AdapterPool(3)
    (p, n), = _prompts(cfg, [(5, 4)], seed=7)

    def serve():
        sched = Scheduler(eng, chunk_size=2, adapters=reg,
                          adapter_pool=apool)
        h = sched.submit(p, n, adapter_id="t0")
        sched.run()
        assert_drained(sched)
        return sched, h

    s1, h1 = serve()
    assert s1.adapter_loads == 1
    s2, h2 = serve()
    assert s2.adapter_loads == 0, "warm pool reloaded resident factors"
    assert apool.hits >= 1 and h1.tokens == h2.tokens


# ---------------------------------------------------------------------------
# Recipe plumbing: AdapterSpec round-trip + validation
# ---------------------------------------------------------------------------

def test_recipe_adapter_roundtrip_and_validation():
    from repro.quant import AdapterSpec
    r = registry.resolve("aser_as", rank=8, adapter_rank=4, adapter_slots=5)
    assert r.adapter == AdapterSpec(rank=4, slots=5) and r.adapter.enabled
    d = r.to_dict()
    assert d["format_version"] == 3 and d["adapter"] == {"rank": 4,
                                                         "slots": 5}
    assert type(r).from_dict(d) == r
    # v2 blobs (no adapter key) load as adapter-free
    d2 = {k: v for k, v in d.items() if k != "adapter"}
    d2["format_version"] = 2
    assert type(r).from_dict(d2).adapter == AdapterSpec()
    with pytest.raises(ValueError, match="slots"):
        AdapterSpec(rank=4, slots=1)
    with pytest.raises(ValueError, match="rank"):
        AdapterSpec(rank=0, slots=4)
    with pytest.raises(ValueError, match="quantized leaves"):
        registry.resolve("fp16", adapter_rank=4, adapter_slots=3)
