"""End-to-end model quantization: calibrate → quantize → serve."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.metrics import perplexity
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models import forward, init_params
from repro.quant import PTQConfig, calibrate, quantize_model, reduce_shared
from repro.runtime import RuntimeConfig

ARCHS = ["llama3_8b", "mamba2_780m", "moonshot_v1_16b", "zamba2_7b"]


@pytest.fixture(scope="module", params=ARCHS)
def quantized(request):
    arch = request.param
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tape = calibrate(params, cfg, corpus.calibration_batches(2, 4, 32))
    tape = reduce_shared(tape, cfg)
    toks = corpus.sample(jnp.asarray(99), 4, 32)
    return arch, cfg, params, tape, toks


@pytest.mark.slow
def test_quantize_all_methods_finite(quantized):
    arch, cfg, params, tape, toks = quantized
    ref, _, _ = forward(params, cfg, toks)
    for method in ["rtn", "smoothquant", "lorc", "l2qer", "aser", "aser_as"]:
        qp = quantize_model(params, tape, PTQConfig(method=method, rank=8,
                                                    outlier_f=8))
        lg, _, _ = forward(qp, cfg, toks)
        assert bool(jnp.all(jnp.isfinite(lg))), (arch, method)
        # quantized model is a perturbation, not garbage
        rel = float(jnp.linalg.norm(lg - ref) / jnp.linalg.norm(ref))
        assert rel < 1.0, (arch, method, rel)


def test_aser_closer_than_rtn(quantized):
    arch, cfg, params, tape, toks = quantized
    ref, _, _ = forward(params, cfg, toks)

    def dist(method, **kw):
        qp = quantize_model(params, tape, PTQConfig(method=method, **kw))
        lg, _, _ = forward(qp, cfg, toks)
        return float(jnp.linalg.norm(lg - ref))

    d_rtn = dist("rtn")
    d_aser = dist("aser_as", rank=16, outlier_f=8)
    assert d_aser < d_rtn, arch


def test_pallas_path_matches_xla(quantized):
    arch, cfg, params, tape, toks = quantized
    if arch != "llama3_8b":
        pytest.skip("one arch suffices (slow in interpret mode)")
    qp = quantize_model(params, tape, PTQConfig(method="aser_as", rank=8,
                                                outlier_f=8))
    lg_xla, _, _ = forward(qp, cfg, toks[:1, :16],
                           rt=RuntimeConfig(use_pallas=False))
    lg_pl, _, _ = forward(qp, cfg, toks[:1, :16],
                          rt=RuntimeConfig(use_pallas=True))
    np.testing.assert_allclose(np.asarray(lg_pl), np.asarray(lg_xla),
                               rtol=1e-3, atol=1e-3)


def test_act_bits_sweep(quantized):
    """W4Ax: lower activation bits → larger deviation (Fig. 5 trend)."""
    arch, cfg, params, tape, toks = quantized
    if arch != "llama3_8b":
        pytest.skip("one arch suffices")
    ref, _, _ = forward(params, cfg, toks)
    qp = quantize_model(params, tape, PTQConfig(method="aser_as", rank=16,
                                                outlier_f=8))
    dists = {}
    for bits in (16, 8, 6):
        lg, _, _ = forward(qp, cfg, toks, rt=RuntimeConfig(a_bits=bits))
        dists[bits] = float(jnp.linalg.norm(lg - ref))
    assert dists[16] <= dists[8] <= dists[6]


@pytest.mark.slow
def test_quantized_decode_consistency(quantized):
    """Quantized model decode == quantized full forward."""
    arch, cfg, params, tape, toks = quantized
    if arch == "moonshot_v1_16b":
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    from repro.models import init_caches
    qp = quantize_model(params, tape, PTQConfig(method="aser_as", rank=8,
                                                outlier_f=8))
    toks = toks[:2, :6]
    full, _, _ = forward(qp, cfg, toks)
    caches = init_caches(cfg, 2, max_len=8)
    outs = []
    for t in range(toks.shape[1]):
        lg, caches, _ = forward(qp, cfg, toks[:, t:t + 1], caches=caches)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    # per-token act quant discretizes: tiny chunked-vs-recurrent numeric
    # differences (SSD path) can flip a code by ±1, so the tolerance is
    # looser than the fp decode test (which is exact to 2e-6).
    assert float(jnp.max(jnp.abs(dec - full))) < 1.5e-2 * float(
        jnp.max(jnp.abs(full)) + 1)
