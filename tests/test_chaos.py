"""Chaos property suite: seeded fault injection against the scheduler.

Each seed drives a deterministic workload through a
:class:`repro.serve.faults.FaultInjector` injecting device faults, NaN
logits, corrupted KV pages and transient pool pressure — plus a
deterministic mid-run cancel and a kill-and-restore through
:class:`CheckpointManager`. The properties asserted after every run:

1. **every** submitted handle reaches a terminal status (nothing hangs);
2. zero leaked pages / adapter references / slots after drain
   (``assert_drained``);
3. every COMPLETED request is **token-exact** against its fault-free
   reference run (greedy decoding: recovery must not change the math);
4. every non-completed terminal request's partial tokens are a prefix of
   that reference;
5. the killed-and-restored scheduler resumes token-exactly.

Failing seeds are replayable: ``CHAOS_SEED=<n>`` pins the matrix to one
seed, and the fault trace is written to ``CHAOS_TRACE_DIR`` (CI uploads
it as the failure artifact). Runs on the XLA path so the one-shot kernel
fallback (tested separately in ``test_lifecycle.py``) cannot perturb
tokens mid-run.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_smoke_config
from repro.models import init_params
from repro.serve.engine import Engine, ServeConfig
from repro.serve.faults import FaultInjector
from repro.serve.lifecycle import (RequestStatus, TERMINAL_STATUSES,
                                   assert_drained)
from repro.serve.scheduler import Scheduler

pytestmark = pytest.mark.slow

SEEDS = ([int(os.environ["CHAOS_SEED"])] if os.environ.get("CHAOS_SEED")
         else [0, 1, 2])


def _tiny_cfg():
    return get_smoke_config("llama3_8b").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32", remat=False)


@pytest.fixture(scope="module")
def base_engine():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2,
                                          kv_layout="paged", block_size=8,
                                          num_blocks=14))
    return cfg, eng, {}


@pytest.fixture(scope="module")
def adapter_engine():
    """Quantized base + int8 KV + two LoRA tenants: the full stack under
    chaos (fault recovery must respect adapter routing and salted
    prefixes; KV corruption lands in scale tensors there)."""
    from repro.data.synthetic import CorpusConfig, SyntheticCorpus
    from repro.quant import calibrate, quantize_model, reduce_shared
    from repro.serve.adapters import AdapterRegistry, install_pools
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tape = reduce_shared(
        calibrate(params, cfg, corpus.calibration_batches(2, 4, 16)), cfg)
    qp = quantize_model(params, tape, "aser_as(rank=8)")
    reg = AdapterRegistry(qp, rank=4)
    reg.add("t0")
    reg.add("t1")
    pooled = install_pools(qp, slots=3, rank=4)
    eng = Engine(pooled, cfg, ServeConfig(max_len=64, batch_slots=2,
                                          kv_layout="paged", block_size=8,
                                          num_blocks=14, kv_dtype="int8"))
    return cfg, eng, {"adapters": reg}


@pytest.fixture(scope="module")
def chunked_engine():
    """Paged engine with chunked + budgeted prefill: the full chaos mix
    plus prefill-chunk-boundary faults lands on a scheduler whose
    admissions hold partial page chains across steps."""
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2,
                                          kv_layout="paged", block_size=8,
                                          num_blocks=14, prefill_chunk=4,
                                          step_token_budget=12))
    return cfg, eng, {}


def _workload(cfg, with_adapters):
    """Deterministic request mix: shared prefixes, varied lengths."""
    key = jax.random.PRNGKey(99)
    shared = np.asarray(jax.random.randint(key, (8,), 0, cfg.vocab_size))
    out = []
    for i, (L, n) in enumerate([(9, 8), (12, 6), (16, 10), (9, 5),
                                (20, 7), (11, 9)]):
        p = np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                          (L,), 0, cfg.vocab_size))
        if i % 2 == 0:
            p = np.concatenate([shared, p[8:]]) if L > 8 else p
        aid = (None, "t0", "t1")[i % 3] if with_adapters else None
        out.append((p, n, aid))
    return out


def _reference(eng, workload, extra):
    """Fault-free per-request truth (one scheduler per request keeps it
    independent of batching/scheduling)."""
    refs = []
    for p, n, aid in workload:
        sched = Scheduler(eng, chunk_size=2, **extra)
        h = sched.submit(p, n, adapter_id=aid)
        sched.run(max_steps=500)
        assert h.status is RequestStatus.COMPLETED
        refs.append(list(h.tokens))
    return refs


def _trace_path(seed, tag):
    d = os.environ.get("CHAOS_TRACE_DIR")
    if not d:
        return None
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"chaos_{tag}_seed{seed}.json")


def _check_invariants(handles, refs, scheds):
    for i, h in enumerate(handles):
        assert h.status in TERMINAL_STATUSES, \
            (i, h.status, "request never reached a terminal status")
        if h.status is RequestStatus.COMPLETED:
            assert h.tokens == refs[i], \
                (i, "completed request diverged from fault-free run")
        else:
            assert h.tokens == refs[i][:len(h.tokens)], \
                (i, h.status, "partial tokens diverged from reference")
    for sched in scheds:
        assert_drained(sched)


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("stack", ["base", "adapter", "chunked"])
def test_chaos_drain(stack, seed, request, tmp_path):
    cfg, eng, extra = request.getfixturevalue(f"{stack}_engine")
    workload = _workload(cfg, with_adapters=bool(extra))
    refs = _reference(eng, workload, extra)

    # p_prefill_fault only fires on chunk dispatches — inert off-chunked
    inj = FaultInjector(seed, p_device=0.06, p_nan=0.08, p_kv_corrupt=0.12,
                        p_pool_hog=0.2, p_adapter_hog=0.15,
                        p_prefill_fault=0.08, max_hog_steps=2)
    sched = Scheduler(eng, chunk_size=2, faults=inj, max_fault_retries=6,
                      stall_limit=30, **extra)
    handles = [sched.submit(p, n, adapter_id=aid)
               for p, n, aid in workload]
    cancel_at, killed_at = 3, 7
    mgr = CheckpointManager(str(tmp_path / "snap"))
    try:
        step = 0
        more = True
        while more and step < 400:
            more = sched.step()
            step += 1
            if step == cancel_at:
                handles[1].cancel()
            if step == killed_at and sched.pending:
                # kill-and-restore through a disk round-trip, mid-chaos
                mgr.save(step, sched.snapshot())
                inj.release_all()
                old, prior_trace = sched, inj.trace
                inj = FaultInjector(seed + 1000, p_device=0.06, p_nan=0.08,
                                    p_kv_corrupt=0.12, p_pool_hog=0.2,
                                    p_adapter_hog=0.15,
                                    p_prefill_fault=0.08, max_hog_steps=2)
                # one trace across the kill: the whole run (both injector
                # phases) replays from the matrix seed alone
                inj.seed = seed
                inj.trace = prior_trace
                inj.trace.append({"step": step, "fault": "kill_restore"})
                sched = Scheduler(eng, chunk_size=2, faults=inj,
                                  max_fault_retries=6, stall_limit=30,
                                  **extra)
                restored = sched.restore(mgr.restore_pytree(step))
                # the snapshot carries exactly the non-terminal requests,
                # and the restored handles adopt their progress
                assert len(restored) == old.pending
                for i, h in enumerate(handles):
                    if not h.done:
                        h2 = restored[h.request.rid]
                        assert h2.tokens[:len(h.tokens)] == h.tokens
                        handles[i] = h2
                more = True
        assert step < 400, "chaos run did not drain"
        inj.quiesce()
        sched.run(max_steps=400)                  # belt-and-braces drain
        _check_invariants(handles, refs, [sched])
        assert handles[1].status in (RequestStatus.CANCELLED,
                                     RequestStatus.COMPLETED,
                                     RequestStatus.FAILED)
    except BaseException:
        path = _trace_path(seed, stack)
        if path:
            inj.save_trace(path, note=f"{stack} seed {seed} FAILED")
        raise
    path = _trace_path(seed, stack)
    if path:
        inj.save_trace(path, note=f"{stack} seed {seed} passed")


@pytest.mark.parametrize("seed", SEEDS)
def test_chaos_checkpoint_write_failures(base_engine, seed, tmp_path):
    """Checkpoint chaos: injected write failures must surface as
    exceptions (sync in place, async on wait/next save), never corrupt
    the latest good step, and never leave partial tmp dirs."""
    cfg, eng, extra = base_engine
    sched = Scheduler(eng, chunk_size=2)
    for p, n, aid in _workload(cfg, with_adapters=False)[:3]:
        sched.submit(p, n)
    sched.step()
    inj = FaultInjector(seed, p_ckpt_fail=0.5)
    mgr = inj.wrap_checkpoint(
        CheckpointManager(str(tmp_path / "ck"), async_save=True))
    good_steps = []
    failures = 0
    for step in range(8):
        try:
            mgr.save(step, sched.snapshot())
            mgr.wait()
            good_steps.append(step)
        except OSError:
            failures += 1
        sched.step()
    mgr.wait()
    assert failures == sum(1 for e in inj.trace
                           if e["fault"] == "ckpt_write_fail")
    assert not [d for d in os.listdir(mgr.dir) if d.startswith("tmp.")], \
        "failed write left a partial tmp dir"
    if good_steps:                    # last good step restores cleanly
        snap = mgr.restore_pytree(good_steps[-1])
        fresh = Scheduler(eng, chunk_size=2)
        fresh.restore(snap)
        fresh.run(max_steps=400)
        assert_drained(fresh)
    sched.run(max_steps=400)
    assert_drained(sched)


# ---------------------------------------------------------------------------
# Prefill-chunk-boundary faults (deterministic, beyond the seeded matrix)
# ---------------------------------------------------------------------------

def test_prefill_fault_quarantines_partial_chain_and_retries(chunked_engine):
    """A device fault on a prefill-chunk boundary quarantines the partial
    page chain (freed + never prefix-registered), and the bounded retry
    re-prefills from scratch token-exactly."""
    cfg, eng, _ = chunked_engine
    p = np.asarray(jax.random.randint(jax.random.PRNGKey(41), (17,),
                                      0, cfg.vocab_size))
    ref = Scheduler(eng, chunk_size=2)
    hr = ref.submit(p, 6)
    ref.run(max_steps=200)

    inj = FaultInjector(0, p_prefill_fault=1.0)
    sched = Scheduler(eng, chunk_size=2, faults=inj, max_fault_retries=4,
                      prefix_reuse=True)
    h = sched.submit(p, 6)
    sched.step()                      # claim, fault, requeue, re-claim
    assert sched.device_faults >= 1 and sched.quarantines >= 1
    assert not h.done and not h.tokens
    assert h.fault_retries >= 1       # bounded-retry accounting ticked
    # the faulted chain was freed wholesale; only the re-claim's fresh
    # chain (ceil((17+1)/8) = 3 pages) is held now
    assert sched.pool.available() == sched.pool.num_blocks - 3
    assert inj.trace and inj.trace[0]["fault"] == "prefill_chunk_fault"
    inj.p_prefill_fault = 0.0         # storm over: retry must complete
    sched.run(max_steps=200)
    assert h.status is RequestStatus.COMPLETED
    assert h.tokens == hr.tokens      # token-exact resume
    # a quarantined partial chain must never have become a prefix hit
    assert sched.prefix_hits == 0
    assert_drained(sched)


def test_prefill_fault_retries_are_bounded(chunked_engine):
    """A permanent prefill fault exhausts max_fault_retries and the
    request terminates FAILED — never an infinite requeue loop — with
    the pool clean."""
    cfg, eng, _ = chunked_engine
    p = np.asarray(jax.random.randint(jax.random.PRNGKey(42), (12,),
                                      0, cfg.vocab_size))
    inj = FaultInjector(0, p_prefill_fault=1.0)
    sched = Scheduler(eng, chunk_size=2, faults=inj, max_fault_retries=3,
                      stall_limit=50)
    h = sched.submit(p, 4)
    sched.run(max_steps=200)
    assert h.status is RequestStatus.FAILED
    assert "prefill-chunk device fault" in h.error
    assert h.fault_retries > 3
    assert not h.tokens
    assert_drained(sched)


def test_snapshot_roundtrips_half_prefilled_request(chunked_engine,
                                                    tmp_path):
    """Kill-and-restore with a request caught mid-prefill: it serializes
    as preempted (prompt, zero tokens) and the restored scheduler
    re-prefills it token-exactly."""
    cfg, eng, _ = chunked_engine
    p_long = np.asarray(jax.random.randint(jax.random.PRNGKey(43), (20,),
                                           0, cfg.vocab_size))
    p_short = np.asarray(jax.random.randint(jax.random.PRNGKey(44), (3,),
                                            0, cfg.vocab_size))
    ref = Scheduler(eng, chunk_size=2)
    r_long, r_short = ref.submit(p_long, 5), ref.submit(p_short, 7)
    ref.run(max_steps=200)

    sched = Scheduler(eng, chunk_size=2)
    h_long, h_short = sched.submit(p_long, 5), sched.submit(p_short, 7)
    sched.step()                      # long: mid-prefill; short: decoding
    assert h_long.status is RequestStatus.RUNNING and not h_long.tokens
    assert any(pp is not None for pp in sched._prefill_prompt)
    mgr = CheckpointManager(str(tmp_path / "snap"))
    mgr.save(1, sched.snapshot())

    fresh = Scheduler(eng, chunk_size=2)
    restored = fresh.restore(mgr.restore_pytree(1))
    assert len(restored) == 2         # the half-prefilled one came along
    h2_long = restored[h_long.request.rid]
    h2_short = restored[h_short.request.rid]
    assert h2_long.tokens == []       # no token yet: plain re-prefill
    assert h2_short.tokens[:len(h_short.tokens)] == h_short.tokens
    fresh.run(max_steps=200)
    assert h2_long.status is RequestStatus.COMPLETED
    assert h2_long.tokens == r_long.tokens
    assert h2_short.tokens == r_short.tokens
    assert_drained(fresh)
