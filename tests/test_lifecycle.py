"""Request lifecycle: terminal statuses, cancel, deadlines, shedding,
stall detection, numeric-guard quarantine, device-fault recovery,
snapshot/restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_smoke_config
from repro.models import init_params
from repro.runtime import RuntimeConfig
from repro.serve.engine import Engine, ServeConfig
from repro.serve.faults import DeviceStepFault, FaultInjector
from repro.serve.lifecycle import (RequestStatus, assert_drained,
                                   check_drained)
from repro.serve.scheduler import Scheduler


def _tiny_cfg():
    return get_smoke_config("llama3_8b").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32", remat=False)


@pytest.fixture(scope="module")
def tiny():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def paged(tiny):
    """One shared paged engine — schedulers each build fresh caches, so
    sharing it across tests only shares the compiled programs."""
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2,
                                          kv_layout="paged", block_size=8,
                                          num_blocks=16))
    return cfg, eng


def _prompt(cfg, L, seed=2):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (L,),
                                         0, cfg.vocab_size))


def _ref(eng, prompt, n):
    return np.asarray(eng.generate(jnp.asarray(prompt[None]), n))[0].tolist()


# ---------------------------------------------------------------------------
# Terminal statuses: completion, cancel, deadlines, shedding
# ---------------------------------------------------------------------------

def test_completed_is_terminal_and_drained(paged):
    cfg, eng = paged
    sched = Scheduler(eng, chunk_size=2)
    h = sched.submit(_prompt(cfg, 9), 5)
    assert h.status is RequestStatus.QUEUED and not h.done
    sched.run()
    assert h.status is RequestStatus.COMPLETED and h.done and h.error is None
    assert sched.lifecycle_stats()["completed"] == 1
    assert_drained(sched)
    h.cancel()                                    # no-op on a terminal handle
    assert h.status is RequestStatus.COMPLETED


def test_cancel_queued_and_running(paged):
    """Cancel tears down at the next chunk boundary: a queued request never
    runs, a running one keeps its partial tokens; no pages leak."""
    cfg, eng = paged
    sched = Scheduler(eng, chunk_size=2)
    h_run = sched.submit(_prompt(cfg, 9, seed=3), 12)
    h_q1 = sched.submit(_prompt(cfg, 40, seed=4), 20)   # 6 pages: must wait
    h_q2 = sched.submit(_prompt(cfg, 40, seed=5), 20)
    sched.step()
    assert h_run.tokens and not h_q2.done
    h_q2.cancel()
    h_run.cancel()
    sched.run()
    assert h_q2.status is RequestStatus.CANCELLED
    assert h_run.status is RequestStatus.CANCELLED
    assert h_q2.tokens == []                       # never admitted
    partial = list(h_run.tokens)
    assert 0 < len(partial) < 12                   # kept its partial tokens
    assert partial == _ref(eng, _prompt(cfg, 9, seed=3), 12)[:len(partial)]
    assert h_q1.status is RequestStatus.COMPLETED  # the rest drain normally
    assert sched.cancelled == 2
    assert_drained(sched)


def test_deadlines_fake_clock(paged):
    """TTFT expires queued requests; the total deadline expires running
    ones (partial tokens intact). Both checked against an injected clock,
    so the test is immune to wall-clock noise."""
    cfg, eng = paged
    clk = [100.0]
    sched = Scheduler(eng, chunk_size=2, clock=lambda: clk[0])
    h_fast = sched.submit(_prompt(cfg, 9, seed=6), 4)           # no deadline
    h_total = sched.submit(_prompt(cfg, 10, seed=8), 30,
                           deadline_ms=200.0)
    h_ttft = sched.submit(_prompt(cfg, 12, seed=7), 8,
                          ttft_ms=50.0)           # both slots taken: queued
    sched.step()                                  # admits fast + total
    assert h_total.tokens and not h_ttft.tokens
    clk[0] += 0.1                                 # +100 ms: TTFT 50 missed
    sched.step()
    assert h_ttft.status is RequestStatus.TIMED_OUT
    assert "TTFT" in h_ttft.error
    clk[0] += 0.2                                 # +200 ms: total missed
    sched.run()
    assert h_total.status is RequestStatus.TIMED_OUT
    assert "total deadline" in h_total.error
    partial = h_total.tokens
    assert 0 < len(partial) < 30                  # partial survives
    assert partial == _ref(eng, _prompt(cfg, 10, seed=8), 30)[:len(partial)]
    assert h_fast.status is RequestStatus.COMPLETED
    assert sched.timed_out == 2
    assert_drained(sched)


def test_queue_cap_load_shedding(paged):
    cfg, eng = paged
    sched = Scheduler(eng, queue_cap=2, chunk_size=2)
    accepted = [sched.submit(_prompt(cfg, 8, seed=i), 3) for i in (10, 11)]
    shed = sched.submit(_prompt(cfg, 8, seed=12), 3)
    assert shed.done and shed.status is RequestStatus.REJECTED
    assert "load shed" in shed.error
    sched.run()
    assert all(h.status is RequestStatus.COMPLETED for h in accepted)
    assert sched.rejected == 1
    assert_drained(sched)


# ---------------------------------------------------------------------------
# Stall detection (the old infinite busy-loop)
# ---------------------------------------------------------------------------

def _adapter_fixture(tiny):
    from repro.data.synthetic import CorpusConfig, SyntheticCorpus
    from repro.quant import calibrate, quantize_model, reduce_shared
    from repro.serve.adapters import AdapterRegistry, install_pools
    cfg, params = tiny
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tape = reduce_shared(
        calibrate(params, cfg, corpus.calibration_batches(2, 4, 16)), cfg)
    qp = quantize_model(params, tape, "aser_as(rank=8)")
    reg = AdapterRegistry(qp, rank=4)
    reg.add("t0")
    reg.add("t1")
    return cfg, install_pools(qp, slots=2, rank=4), reg   # ONE adapter slot


def test_stall_detector_fails_unadmittable_request(tiny):
    """An unadmittable request (its adapter can never get a slot while
    another tenant pins the only one) is FAILED by the no-progress
    detector instead of spinning run() forever — the satellite-1 bug."""
    from repro.serve.adapters import AdapterPool
    cfg, pooled, reg = _adapter_fixture(tiny)
    eng = Engine(pooled, cfg, ServeConfig(max_len=32, batch_slots=1))
    apool = AdapterPool(2)
    assert apool.acquire("t0") is not None        # external pin: slot taken
    sched = Scheduler(eng, adapters=reg, adapter_pool=apool, stall_limit=3)
    h = sched.submit(_prompt(cfg, 6, seed=13), 4, adapter_id="t1")
    sched.run(max_steps=50)                       # terminates, no spin
    assert h.status is RequestStatus.FAILED
    assert "stalled" in h.error
    apool.release("t0")
    assert_drained(sched)


def test_run_max_steps_guard(tiny):
    """run(max_steps=...) raises rather than looping when something keeps
    the scheduler busy past any sane bound."""
    from repro.serve.adapters import AdapterPool
    cfg, pooled, reg = _adapter_fixture(tiny)
    eng = Engine(pooled, cfg, ServeConfig(max_len=32, batch_slots=1))
    apool = AdapterPool(2)
    assert apool.acquire("t0") is not None
    sched = Scheduler(eng, adapters=reg, adapter_pool=apool,
                      stall_limit=10_000)         # detector effectively off
    sched.submit(_prompt(cfg, 6, seed=13), 4, adapter_id="t1")
    with pytest.raises(RuntimeError, match="max_steps"):
        sched.run(max_steps=5)
    apool.release("t0")


# ---------------------------------------------------------------------------
# Numeric guard: quarantine + one-shot kernel fallback
# ---------------------------------------------------------------------------

def test_kv_corruption_quarantined_token_exact(paged):
    """nan written into a live KV page trips the on-device finite guard;
    the slot is quarantined (pages invalidated + scrubbed), the request
    retries and still produces the exact fault-free tokens."""
    cfg, eng = paged
    p, n = _prompt(cfg, 17, seed=14), 6
    want = _ref(eng, p, n)
    sched = Scheduler(eng, chunk_size=2)
    h = sched.submit(p, n)
    sched.step()                                   # admitted, some tokens
    assert not h.done
    bad_block = sched._slot_blocks[0][0]           # a page the request owns
    sched._caches = eng.fill_blocks(sched._caches, [bad_block],
                                    float("nan"))
    sched.run()
    assert h.status is RequestStatus.COMPLETED
    assert h.tokens == want
    assert sched.quarantines >= 1 and h.fault_retries >= 1
    assert_drained(sched)


def test_nan_retries_exhaust_to_failed(paged):
    """A slot that goes non-finite every single chunk exhausts its retry
    budget and terminates FAILED — co-batched work is unaffected."""
    cfg, eng = paged
    inj = FaultInjector(seed=5, p_nan=1.0)
    sched = Scheduler(eng, chunk_size=2, faults=inj, max_fault_retries=2)
    h = sched.submit(_prompt(cfg, 9, seed=15), 6)
    sched.run(max_steps=200)
    assert h.status is RequestStatus.FAILED
    assert "non-finite" in h.error and h.fault_retries == 3
    assert sched.quarantines == 3
    assert_drained(sched)


def test_reference_fallback_one_shot(tiny):
    """First quarantine on a Pallas engine reroutes it to the reference
    path exactly once; XLA engines have nothing to fall back from."""
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=32, batch_slots=1),
                 rt=RuntimeConfig(use_pallas=True, interpret=True))
    assert eng.activate_reference_fallback() is True
    assert eng.rt.force_reference and eng.fallback_active
    assert eng.activate_reference_fallback() is False      # one-shot
    xla = Engine(params, cfg, ServeConfig(max_len=32, batch_slots=1))
    assert xla.activate_reference_fallback() is False


def test_fallback_matches_reference_tokens(tiny):
    """After the fallback flips, generation equals the pure-XLA reference
    engine token-for-token (the kernels are pinned to the same math, so
    this holds before the flip too — the invariant that makes mid-stream
    fallback token-exact)."""
    cfg, params = tiny
    p, n = _prompt(cfg, 9, seed=16), 5
    xla = Engine(params, cfg, ServeConfig(max_len=32, batch_slots=1))
    want = _ref(xla, p, n)
    eng = Engine(params, cfg, ServeConfig(max_len=32, batch_slots=1),
                 rt=RuntimeConfig(use_pallas=True, interpret=True))
    assert _ref(eng, p, n) == want
    eng.activate_reference_fallback()
    assert _ref(eng, p, n) == want


# ---------------------------------------------------------------------------
# Device-fault recovery
# ---------------------------------------------------------------------------

def test_device_fault_preempts_and_resumes_token_exact(paged):
    """A decode dispatch failure preempts every active request; the drain
    resumes them token-exactly through re-prefill."""
    cfg, eng = paged
    specs = [(_prompt(cfg, 9, seed=20), 8), (_prompt(cfg, 12, seed=21), 6)]
    want = [_ref(eng, p, n) for p, n in specs]
    inj = FaultInjector(seed=0, p_device=0.0)
    sched = Scheduler(eng, chunk_size=2, faults=inj)
    handles = [sched.submit(p, n) for p, n in specs]
    sched.step()                                   # both running
    inj.p_device = 1.0
    sched.step()                                   # dispatch fails: preempt
    inj.p_device = 0.0
    assert sched.device_faults == 1
    assert all(h.status is RequestStatus.QUEUED for h in handles)
    sched.run()
    assert [h.tokens for h in handles] == want
    assert all(h.fault_retries == 1 for h in handles)
    assert_drained(sched)


# ---------------------------------------------------------------------------
# Snapshot / restore
# ---------------------------------------------------------------------------

def test_snapshot_restore_token_exact(paged, tmp_path):
    """Kill-and-restore mid-flight: the snapshot round-trips through
    CheckpointManager on disk, a fresh scheduler restores it, and every
    request finishes with exactly its fault-free tokens."""
    cfg, eng = paged
    specs = [(_prompt(cfg, 9, seed=30), 10), (_prompt(cfg, 12, seed=31), 8),
             (_prompt(cfg, 40, seed=32), 6)]      # 3rd waits in the queue
    want = [_ref(eng, p, n) for p, n in specs]
    sched = Scheduler(eng, chunk_size=2)
    handles = [sched.submit(p, n) for p, n in specs]
    sched.step()                                   # two in flight, one queued
    assert any(h.tokens for h in handles) and sched.pending == 3
    snap = sched.snapshot()
    assert len(snap["requests"]) == 3

    mgr = CheckpointManager(str(tmp_path / "sched"))
    mgr.save(7, snap)
    del sched                                      # "crash"

    fresh = Scheduler(eng, chunk_size=2)
    restored = fresh.restore(mgr.restore_pytree(7))
    assert sorted(restored) == [h.request.rid for h in handles]
    fresh.run()
    for (p, n), tokens, (rid, h2) in zip(specs, want,
                                         sorted(restored.items())):
        assert h2.status is RequestStatus.COMPLETED
        assert h2.tokens == tokens, rid
    assert fresh._next_rid >= 3                    # rid space preserved
    assert_drained(fresh)


def test_restore_guards(paged):
    cfg, eng = paged
    sched = Scheduler(eng, chunk_size=2)
    sched.submit(_prompt(cfg, 8, seed=33), 3)
    snap = sched.snapshot()
    with pytest.raises(ValueError, match="fresh"):
        sched.restore(snap)                        # non-empty target
    fresh = Scheduler(eng, chunk_size=2)
    with pytest.raises(ValueError, match="format"):
        fresh.restore({"format": np.int64(99), "next_rid": np.int64(0),
                       "requests": {}})
    sched.run()
    assert_drained(sched)


def test_check_drained_reports_leaks(paged):
    """The auditor actually sees a leak (not vacuously empty)."""
    cfg, eng = paged
    sched = Scheduler(eng, chunk_size=2)
    sched.submit(_prompt(cfg, 9, seed=34), 8)
    sched.step()                                   # mid-flight: not drained
    issues = check_drained(sched)
    assert any("occupied" in s for s in issues)
    assert any("non-terminal" in s for s in issues)
    with pytest.raises(AssertionError, match="leaked"):
        assert_drained(sched)
    sched.run()
    assert_drained(sched)


# ---------------------------------------------------------------------------
# Chunked prefill: deadlines + cancellation enforced BETWEEN chunks
# ---------------------------------------------------------------------------

def _chunked_paged(tiny, **kw):
    cfg, params = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=64, batch_slots=2,
                                          kv_layout="paged", block_size=8,
                                          num_blocks=16, prefill_chunk=4,
                                          **kw))
    return cfg, eng


def test_ttft_deadline_enforced_mid_prefill(tiny):
    """The TTFT-gap fix: a mid-prefill request (RUNNING, no token yet)
    must expire at a chunk boundary when its TTFT deadline passes — with
    one-shot prefill a long prompt could sail past ``ttft_ms`` inside a
    single admission dispatch. The partial page chain must be freed and
    the drain must be clean."""
    cfg, eng = _chunked_paged(tiny)
    clk = [50.0]
    sched = Scheduler(eng, chunk_size=2, clock=lambda: clk[0])
    h = sched.submit(_prompt(cfg, 20, seed=31), 8, ttft_ms=40.0)
    sched.step()                      # claim + first chunk(s): mid-prefill
    assert h.status is RequestStatus.RUNNING and not h.tokens
    assert any(p is not None for p in sched._prefill_prompt), \
        "request should be mid-prefill"
    baseline = sched.pool.available()
    assert baseline < sched.pool.num_blocks     # chain is held
    clk[0] += 0.1                     # +100 ms: TTFT 40 ms long gone
    sched.step()                      # next chunk boundary enforces it
    assert h.status is RequestStatus.TIMED_OUT
    assert "TTFT" in h.error and not h.tokens
    assert sched.pool.available() == sched.pool.num_blocks  # chain freed
    assert sched.pending == 0
    assert_drained(sched)


def test_total_deadline_enforced_mid_prefill(tiny):
    cfg, eng = _chunked_paged(tiny)
    clk = [10.0]
    sched = Scheduler(eng, chunk_size=2, clock=lambda: clk[0])
    h = sched.submit(_prompt(cfg, 20, seed=32), 8, deadline_ms=30.0)
    sched.step()
    assert h.status is RequestStatus.RUNNING
    clk[0] += 0.1
    sched.step()
    assert h.status is RequestStatus.TIMED_OUT
    assert "total deadline" in h.error
    assert_drained(sched)


def test_cancel_mid_prefill_frees_chain(tiny):
    """cancel() between chunks tears the claim down at the next boundary:
    no token, no leak, CANCELLED terminal."""
    cfg, eng = _chunked_paged(tiny)
    sched = Scheduler(eng, chunk_size=2)
    h = sched.submit(_prompt(cfg, 20, seed=33), 8)
    sched.step()
    assert h.status is RequestStatus.RUNNING and not h.tokens
    h.cancel()
    sched.step()
    assert h.status is RequestStatus.CANCELLED and not h.tokens
    assert sched.cancelled == 1
    assert sched.pool.available() == sched.pool.num_blocks
    assert_drained(sched)


def test_ttft_met_by_chunked_prefill_completes(tiny):
    """Control for the gap fix: a chunked prefill that finishes inside
    its TTFT budget completes normally and stamps first_token_at."""
    cfg, eng = _chunked_paged(tiny)
    clk = [5.0]
    sched = Scheduler(eng, chunk_size=2, clock=lambda: clk[0])
    h = sched.submit(_prompt(cfg, 20, seed=34), 4, ttft_ms=1000.0)
    sched.run()
    assert h.status is RequestStatus.COMPLETED
    assert h.tokens == _ref(eng, _prompt(cfg, 20, seed=34), 4)
    t = h.timing
    assert t.submitted_at == t.admitted_at == t.first_token_at == 5.0
    assert len(t.prefill_chunks) == 5          # ceil(20 / 4)
    assert t.finished_at is not None and t.ttft() == 0.0
