"""Decode fast path: scan-loop vs step-loop parity, donated-cache
correctness, eos early-stop, and pack-time rank padding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models import forward, init_params
from repro.quant import calibrate, quantize_model, reduce_shared
from repro.runtime import RuntimeConfig
from repro.serve.engine import Engine, ServeConfig


def _tiny_cfg():
    return get_smoke_config("llama3_8b").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32", remat=False)


@pytest.fixture(scope="module")
def tiny():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 5), 0,
                                 cfg.vocab_size)
    return cfg, params, prompts


def _gen(params, cfg, prompts, n_steps, *, loop, temperature=0.0,
         eos_id=-1, seed=0, rt=None):
    eng = Engine(params, cfg,
                 ServeConfig(max_len=32, temperature=temperature,
                             eos_id=eos_id, decode_loop=loop), rt=rt)
    return eng.generate(prompts, n_steps, seed=seed)


# ---------------------------------------------------------------------------
# Scan vs step parity
# ---------------------------------------------------------------------------

def test_scan_matches_step_greedy(tiny):
    cfg, params, prompts = tiny
    out_scan = _gen(params, cfg, prompts, 8, loop="scan")
    out_step = _gen(params, cfg, prompts, 8, loop="step")
    assert out_scan.shape == (3, 8)
    assert jnp.all(out_scan == out_step)


def test_scan_matches_step_sampled(tiny):
    """Same PRNG key-split schedule in both loops ⇒ identical samples."""
    cfg, params, prompts = tiny
    for seed in (0, 7):
        out_scan = _gen(params, cfg, prompts, 8, loop="scan",
                        temperature=0.8, seed=seed)
        out_step = _gen(params, cfg, prompts, 8, loop="step",
                        temperature=0.8, seed=seed)
        assert jnp.all(out_scan == out_step), seed
    # different seeds genuinely sample differently
    a = _gen(params, cfg, prompts, 8, loop="scan", temperature=0.8, seed=0)
    b = _gen(params, cfg, prompts, 8, loop="scan", temperature=0.8, seed=7)
    assert not jnp.all(a == b)


def test_scan_matches_full_forward(tiny):
    """Donated-cache scan decode reproduces the cache-free full forward."""
    cfg, params, _ = tiny
    prompts = jax.random.randint(jax.random.PRNGKey(5), (1, 4), 0,
                                 cfg.vocab_size)
    gen = _gen(params, cfg, prompts, 4, loop="scan")
    seq = jnp.concatenate([prompts, gen[:, :-1]], axis=1)
    logits, _, _ = forward(params, cfg, seq)
    expect = jnp.argmax(logits[:, prompts.shape[1] - 1:], axis=-1)
    assert jnp.all(expect == gen)


def test_donated_caches_fresh_per_call(tiny):
    """Donation must not leak state across generate() calls: repeated and
    interleaved calls (different n_steps buckets) all agree."""
    cfg, params, prompts = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=32))
    a = eng.generate(prompts, 8)
    short = eng.generate(prompts, 3)          # different compiled bucket
    b = eng.generate(prompts, 8)
    assert jnp.all(a == b)
    assert jnp.all(a[:, :3] == short)


# ---------------------------------------------------------------------------
# eos_id early stop (masked continuation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("loop", ["scan", "step"])
def test_eos_masks_continuation(tiny, loop):
    cfg, params, prompts = tiny
    free = _gen(params, cfg, prompts, 8, loop=loop)
    # pick the token slot 0 emits mid-generation as the eos id
    eos = int(free[0, 3])
    out = _gen(params, cfg, prompts, 8, loop=loop, eos_id=eos)
    got = np.asarray(out)
    for row in got:
        hits = np.nonzero(row == eos)[0]
        if hits.size:
            assert np.all(row[hits[0]:] == eos), row
    # slot 0 definitely finished at (or before) step 3
    assert np.all(got[0, 3:] == eos)
    # pre-eos prefix is unchanged from the unconstrained run
    stop = int(np.nonzero(got[0] == eos)[0][0])
    assert np.all(got[0, :stop] == np.asarray(free)[0, :stop])


def test_eos_never_when_disabled(tiny):
    """eos_id = -1 (seed default) must not alter generation."""
    cfg, params, prompts = tiny
    out = _gen(params, cfg, prompts, 6, loop="scan", eos_id=-1)
    ref = _gen(params, cfg, prompts, 6, loop="step", eos_id=-1)
    assert jnp.all(out == ref)


def test_bad_decode_loop_rejected():
    with pytest.raises(ValueError, match="decode_loop"):
        ServeConfig(decode_loop="vectorized")


# ---------------------------------------------------------------------------
# n_steps edge cases ([b, 0], not an unconditionally-emitted prefill token)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("loop", ["scan", "step"])
def test_zero_steps_returns_empty(tiny, loop):
    cfg, params, prompts = tiny
    out = _gen(params, cfg, prompts, 0, loop=loop)
    assert out.shape == (prompts.shape[0], 0)
    assert out.dtype == jnp.int32


def test_one_step_is_prefill_token_only(tiny):
    cfg, params, prompts = tiny
    one = _gen(params, cfg, prompts, 1, loop="scan")
    eight = _gen(params, cfg, prompts, 8, loop="scan")
    assert one.shape == (prompts.shape[0], 1)
    assert jnp.all(one == eight[:, :1])


# ---------------------------------------------------------------------------
# Step-loop (debug path) donates caches into the per-token dispatch
# ---------------------------------------------------------------------------

def test_step_loop_decode_donates_caches(tiny):
    """Without donate_argnums on the per-token step, every debug-loop token
    copies the full KV tree. Checked via the lowered ArgInfo flags: the
    caches argument (and only large cache buffers) must be donated."""
    from repro.models import init_caches
    cfg, params, prompts = tiny
    eng = Engine(params, cfg, ServeConfig(max_len=32))
    caches = init_caches(cfg, prompts.shape[0], 32)
    tok = jnp.zeros((prompts.shape[0],), jnp.int32)
    key = jax.random.PRNGKey(0)

    def donated_flags(lowered, argnum):
        info = lowered.args_info[0][argnum]
        return [a.donated for a in jax.tree.leaves(info)]

    low = eng._decode.lower(params, tok, caches, key)
    assert all(donated_flags(low, 2)), "caches must be donated"
    assert not any(donated_flags(low, 0)), "params must NOT be donated"

    pos = jnp.zeros((prompts.shape[0],), jnp.int32)
    low_r = eng._decode_ragged.lower(params, tok, caches, key, pos)
    assert all(donated_flags(low_r, 2))


def test_step_scan_parity_survives_donation(tiny):
    """Donated step-loop still produces the scan loop's tokens (the step
    path must not read a buffer it already gave away)."""
    cfg, params, prompts = tiny
    for temp, seed in ((0.0, 0), (0.8, 3)):
        a = _gen(params, cfg, prompts, 8, loop="scan", temperature=temp,
                 seed=seed)
        b = _gen(params, cfg, prompts, 8, loop="step", temperature=temp,
                 seed=seed)
        assert jnp.all(a == b), (temp, seed)


# ---------------------------------------------------------------------------
# Quantized serving through the scan loop (fused decode kernel on hot path)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_quant(tiny):
    cfg, params, _ = tiny
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tape = reduce_shared(
        calibrate(params, cfg, corpus.calibration_batches(2, 4, 16)), cfg)
    return quantize_model(params, tape, "aser_as")


def test_quantized_scan_matches_step_pallas(tiny, tiny_quant):
    """b=1 decode routes through the fused kernel (m=1): scan-pallas ==
    step-XLA token-for-token on the quantized model."""
    cfg, _, _ = tiny
    prompts = jax.random.randint(jax.random.PRNGKey(9), (1, 4), 0,
                                 cfg.vocab_size)
    out_pl = _gen(tiny_quant, cfg, prompts, 5, loop="scan",
                  rt=RuntimeConfig(use_pallas=True))
    out_xla = _gen(tiny_quant, cfg, prompts, 5, loop="step",
                   rt=RuntimeConfig(use_pallas=False))
    assert jnp.all(out_pl == out_xla)


def test_pack_time_rank_padding(tiny):
    """Odd requested rank ⇒ leaves come out lane-aligned (multiple of 8),
    and the padded factors are inert: XLA ref == pallas paths."""
    from repro.kernels.ops import LOWRANK_MULTIPLE
    cfg, params, _ = tiny
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))
    tape = reduce_shared(
        calibrate(params, cfg, corpus.calibration_batches(2, 4, 16)), cfg)
    qp = quantize_model(params, tape, "aser(rank=13)")

    ranks = []

    def walk(node):
        if isinstance(node, dict):
            if "lb" in node:
                ranks.append(node["lb"].shape[-1])
            else:
                for v in node.values():
                    walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)
    walk(qp)
    assert ranks and all(r % LOWRANK_MULTIPLE == 0 and r >= 13
                         for r in ranks), ranks

    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0,
                              cfg.vocab_size)
    lg_ref, _, _ = forward(qp, cfg, toks, rt=RuntimeConfig(use_pallas=False))
    lg_pl, _, _ = forward(qp, cfg, toks, rt=RuntimeConfig(use_pallas=True))
    np.testing.assert_allclose(np.asarray(lg_pl), np.asarray(lg_ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# serve_bench schema contract (what the CI smoke step enforces)
# ---------------------------------------------------------------------------

def test_serve_bench_validator():
    import importlib
    sb = importlib.import_module("benchmarks.serve_bench")
    row = {f: 1.0 for f in sb.ROW_FIELDS}
    crow = {f: 1.0 for f in sb.CONT_ROW_FIELDS}
    # v6 rows carry the steady-state sanitizer counters, pinned to zero
    crow6 = dict({f: 1.0 for f in sb.CONT_ROW_FIELDS_V6},
                 **{f: 0 for f in sb.SANITIZER_FIELDS})
    prow = {f: 1.0 for f in sb.PREFIX_ROW_FIELDS}
    krow = {f: 1.0 for f in sb.KV_ROW_FIELDS}
    arow = {f: 1.0 for f in sb.ADAPTER_ROW_FIELDS}
    arow.update(mode="w4a8_aser", token_exact=True)
    # v7 latency rows: chunked steady-state counters pinned to zero
    lrow = dict({f: 1.0 for f in sb.LATENCY_ROW_FIELDS},
                chunked_recompiles_after_warmup=0,
                chunked_h2d_transfers_per_step=0)
    # v8 static rows carry the measured-autotune columns
    row8 = dict(row, decode_tokens_per_s=1.0, autotune="off",
                decode_plan="default", displaced_decode_ms_per_tok=1.0,
                autotune_demoted=False, decode_vs_fp=1.0)
    rows = [dict(row, mode="fp"), dict(row, mode="w4a8_aser")]
    rows8 = [dict(row8, mode="fp"),
             dict(row8, mode="w4a8_aser", autotune="force",
                  decode_plan="prepared")]
    crows = [dict(crow, mode="fp"), dict(crow, mode="w4a8_aser")]
    crows6 = [dict(crow6, mode="fp"), dict(crow6, mode="w4a8_aser")]
    prows = [dict(prow, mode="fp"), dict(prow, mode="w4a8_aser")]
    krows = [dict(krow, mode="fp"), dict(krow, mode="w4a8_aser")]
    lrows = [dict(lrow, mode="fp"), dict(lrow, mode="w4a8_aser")]
    good = {"schema": sb.SCHEMA, "smoke": True, "rows": rows8,
            "continuous_rows": crows6, "prefix_rows": prows,
            "kv_rows": krows, "adapter_rows": [arow],
            "latency_rows": lrows}
    assert sb.validate(good)
    # v6 files neither need nor get latency rows enforced
    assert sb.validate({"schema": sb.SCHEMA_V6, "smoke": True, "rows": rows,
                        "continuous_rows": crows6, "prefix_rows": prows,
                        "kv_rows": krows, "adapter_rows": [arow]})
    # v1/v2/v3/v4 generations must keep validating
    assert sb.validate({"schema": sb.SCHEMA_V1, "smoke": True, "rows": rows})
    assert sb.validate({"schema": sb.SCHEMA_V2, "smoke": True, "rows": rows,
                        "continuous_rows": crows})
    assert sb.validate({"schema": sb.SCHEMA_V3, "smoke": True, "rows": rows,
                        "continuous_rows": crows, "prefix_rows": prows})
    assert sb.validate({"schema": sb.SCHEMA_V4, "smoke": True, "rows": rows,
                        "continuous_rows": crows, "prefix_rows": prows,
                        "kv_rows": krows})
    assert sb.validate({"schema": sb.SCHEMA_V5, "smoke": True, "rows": rows,
                        "continuous_rows": crows, "prefix_rows": prows,
                        "kv_rows": krows, "adapter_rows": [arow]})
    with pytest.raises(ValueError):
        sb.validate({"schema": "nope", "rows": rows})
    with pytest.raises(ValueError):
        sb.validate(dict(good, rows=[dict(row8, mode="fp")]))
    bad = dict(row8, mode="fp", prefill_ms=float("nan"))
    with pytest.raises(ValueError):
        sb.validate(dict(good, rows=[bad, dict(row8, mode="w4a8_aser")]))
    # v2 without goodput rows is invalid; v2 demands positive goodput
    with pytest.raises(ValueError, match="continuous"):
        sb.validate({"schema": sb.SCHEMA_V2, "rows": rows})
    with pytest.raises(ValueError):
        sb.validate({"schema": sb.SCHEMA_V2, "rows": rows,
                     "continuous_rows": [
                         dict(crow, mode="fp", goodput_tok_s=0.0),
                         dict(crow, mode="w4a8_aser")]})
    # v3 without prefix rows is invalid; hit rate must sit in (0, 1]
    with pytest.raises(ValueError, match="prefix"):
        sb.validate({"schema": sb.SCHEMA_V3, "rows": rows,
                     "continuous_rows": crows})
    with pytest.raises(ValueError, match="hit_rate"):
        sb.validate({"schema": sb.SCHEMA_V3, "rows": rows,
                     "continuous_rows": crows,
                     "prefix_rows": [
                         dict(prow, mode="fp", prefix_hit_rate=1.5),
                         dict(prow, mode="w4a8_aser")]})
    # v4 without kv rows is invalid (deeper kv-row checks:
    # tests/test_serve_bench_schema.py)
    with pytest.raises(ValueError, match="kv rows"):
        sb.validate({k: v for k, v in good.items() if k != "kv_rows"})
