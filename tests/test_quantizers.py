import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # fallback: deterministic samples, see _propstub
    from _propstub import given, settings, st

from repro.core.quantizers import (A6, A8, W4, W8, QuantConfig,
                                   dequantize_weight, fake_quant_activation,
                                   fake_quant_weight, pack_int4,
                                   quantize_activation, quantize_weight,
                                   unpack_int4)


def test_pack_unpack_roundtrip(rng):
    codes = jnp.asarray(rng.integers(-8, 8, size=(64, 128)), jnp.int8)
    assert jnp.all(unpack_int4(pack_int4(codes)) == codes)


def test_pack_halves_size(rng):
    codes = jnp.asarray(rng.integers(-8, 8, size=(32, 64)), jnp.int8)
    assert pack_int4(codes).shape == (32, 32)


@pytest.mark.parametrize("cfg", [W4, W8, QuantConfig(bits=4, granularity="per_tensor"),
                                 QuantConfig(bits=4, granularity="per_group",
                                             group_size=32)])
def test_weight_roundtrip_error_bound(rng, cfg):
    w = jnp.asarray(rng.normal(size=(48, 64)).astype(np.float32))
    codes, scale = quantize_weight(w, cfg)
    deq = dequantize_weight(codes, scale, cfg)
    # error bounded by half a quantization step everywhere
    if cfg.granularity == "per_tensor":
        step = scale
    elif cfg.granularity == "per_group":
        step = jnp.repeat(scale, cfg.group_size, axis=-1)
    else:
        step = scale
    assert jnp.all(jnp.abs(w - deq) <= step * 0.5 + 1e-6)


def test_weight_codes_in_range(rng):
    w = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32) * 100)
    codes, _ = quantize_weight(w, W4)
    assert codes.min() >= -8 and codes.max() <= 7


def test_activation_per_token_scales(rng):
    x = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    x = x.at[3].mul(100.0)
    codes, scale = quantize_activation(x, A8)
    assert scale.shape == (8, 1)
    assert scale[3] > 10 * scale[0]


def test_fake_quant_monotone_bits(rng):
    x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    e8 = jnp.linalg.norm(x - fake_quant_activation(x, A8))
    e6 = jnp.linalg.norm(x - fake_quant_activation(x, A6))
    assert e8 < e6


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 8), st.integers(1, 64), st.integers(2, 64))
def test_weight_quant_property(bits, out, inn):
    rng = np.random.default_rng(bits * 1000 + out * 10 + inn)
    w = jnp.asarray(rng.normal(size=(out, inn)).astype(np.float32))
    cfg = QuantConfig(bits=bits)
    wq = fake_quant_weight(w, cfg)
    # error bounded by half a step per element
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=1, keepdims=True), 1e-8) / cfg.qmax
    assert jnp.all(jnp.abs(w - wq) <= scale * 0.5 + 1e-6)
    # idempotence: quantizing a quantized weight is (near-)identity
    wq2 = fake_quant_weight(wq, cfg)
    assert float(jnp.max(jnp.abs(wq - wq2))) < 1e-5
    # zero maps to zero (symmetric)
    assert jnp.all(fake_quant_weight(jnp.zeros_like(w), cfg) == 0)
