"""Pytest plugin: runtime sanitizer fixtures for the serving stack.

Imported by ``tests/conftest.py`` so every test can assert the two
steady-state invariants the static analyzer can't prove alone:

* ``retrace_counter`` — context manager counting backend compilations
  (``with retrace_counter() as cc: ...; assert cc.count == 0``);
* ``transfer_guard`` — context manager forbidding *implicit* device↔host
  transfers (explicit ``jax.device_get``/``jnp.asarray`` stay legal);
* ``steady_state_audit`` — warm-up-then-replay driver returning a
  :class:`repro.analysis.sanitizers.SteadyStateReport`.

The mechanisms live in ``repro.analysis.sanitizers`` and are shared with
``benchmarks/serve_bench.py``, which records the same two counters into
the ``serve_bench/v6`` schema — CI enforces zero on both paths.
"""
import pytest

from repro.analysis.sanitizers import (audit_steady_state, compile_counter,
                                       no_implicit_transfers)


@pytest.fixture
def retrace_counter():
    return compile_counter


@pytest.fixture
def transfer_guard():
    return no_implicit_transfers


@pytest.fixture
def steady_state_audit():
    return audit_steady_state
