import os
import sys

# smoke tests / benches must see ONE device; only the dry-run sets 512.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
