import os
import sys

# smoke tests / benches must see ONE device; only the dry-run sets 512.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

# runtime sanitizer fixtures (retrace_counter / transfer_guard /
# steady_state_audit) — imported so pytest discovers them everywhere
from sanitizers import (retrace_counter, transfer_guard,  # noqa: E402,F401
                        steady_state_audit)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Per-test wall-clock budget (the CI fast job's honesty gate)
#
# REPRO_FAST_TEST_BUDGET_S=<seconds> makes the session FAIL if any test not
# marked ``slow`` exceeds the budget in its call phase. The fast CI job sets
# it, so a test that grows past the budget must either get faster or be
# marked ``@pytest.mark.slow`` (moving it to the slow job) — the growing
# serving suite can't silently turn the fast signal into a 30-minute one.
# Unset (the default, and the tier-1 command) it does nothing.
# ---------------------------------------------------------------------------

_BUDGET_S = float(os.environ.get("REPRO_FAST_TEST_BUDGET_S", "0") or 0)
_OVER_BUDGET = []


def pytest_runtest_logreport(report):
    if (_BUDGET_S > 0 and report.when == "call" and report.passed
            and "slow" not in report.keywords
            and report.duration > _BUDGET_S):
        _OVER_BUDGET.append((report.nodeid, report.duration))


def pytest_terminal_summary(terminalreporter):
    if not _OVER_BUDGET:
        return
    terminalreporter.section(
        f"unmarked tests over the {_BUDGET_S:.0f}s fast-job budget")
    for nodeid, dur in sorted(_OVER_BUDGET, key=lambda t: -t[1]):
        terminalreporter.write_line(
            f"  {dur:7.1f}s  {nodeid}  (speed it up or mark it slow)")


def pytest_sessionfinish(session, exitstatus):
    if _OVER_BUDGET and session.exitstatus == 0:
        session.exitstatus = 1
