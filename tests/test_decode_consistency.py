"""Serving correctness: token-by-token decode with caches must reproduce the
full-sequence forward for every architecture family."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import (encode, forward, init_caches, init_params,
                          prepare_cross_caches)

FAMS = ["stablelm_3b", "gemma2_9b", "mamba2_780m", "zamba2_7b",
        "moonshot_v1_16b", "whisper_medium", "qwen2_vl_7b", "nemotron_4_340b"]


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_prefill(arch, key):
    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)  # no token drops
    params = init_params(key, cfg)
    b, s = 2, 10
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    kwargs = {}
    mrope = None
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        mrope = jnp.stack([pos, pos, pos])
        kwargs["mrope_positions"] = mrope
    eo = None
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model))
        eo = encode(params, cfg, frames)
        kwargs["encoder_out"] = eo
    full, _, _ = forward(params, cfg, tokens, **kwargs)

    caches = init_caches(cfg, b, max_len=16)
    if cfg.family == "encdec":
        caches = prepare_cross_caches(params, cfg, eo, caches)
    outs = []
    for t in range(s):
        kw = {}
        if mrope is not None:
            kw["mrope_positions"] = mrope[:, :, t:t + 1]
        lg, caches, _ = forward(params, cfg, tokens[:, t:t + 1],
                                caches=caches, **kw)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(dec - full))) < 5e-4 * float(
        jnp.max(jnp.abs(full)) + 1)


def test_chunked_prefill_matches(key):
    """Prefill in two chunks == prefill in one (chunked-prefill serving)."""
    cfg = get_smoke_config("stablelm_3b")
    params = init_params(key, cfg)
    b, s = 2, 12
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full, _, _ = forward(params, cfg, tokens)

    caches = init_caches(cfg, b, max_len=16)
    lg1, caches, _ = forward(params, cfg, tokens[:, :7], caches=caches)
    lg2, caches, _ = forward(params, cfg, tokens[:, 7:], caches=caches)
    got = jnp.concatenate([lg1, lg2], axis=1)
    assert float(jnp.max(jnp.abs(got - full))) < 5e-4 * float(
        jnp.max(jnp.abs(full)) + 1)


def test_sliding_window_ring_buffer(key):
    """Ring-buffer decode == full-cache decode for a windowed layer."""
    cfg = get_smoke_config("gemma2_9b")
    params = init_params(key, cfg)
    b, s = 1, 24
    win = cfg.sliding_window
    assert win < s or win == 64  # smoke window is 64 > s → widen seq
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full, _, _ = forward(params, cfg, tokens)
    caches = init_caches(cfg, b, max_len=s)  # ring for local layers
    outs = []
    for t in range(s):
        lg, caches, _ = forward(params, cfg, tokens[:, t:t + 1], caches=caches)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(dec - full))) < 5e-4 * float(
        jnp.max(jnp.abs(full)) + 1)
