"""Serving correctness: token-by-token decode with caches must reproduce the
full-sequence forward for every architecture family — and the engine's
optimized serving paths (scan vs step decode loop, contiguous vs paged KV)
must agree token-for-token on every registry config that supports them.
Configs that cannot serve ragged/paged (SSM/hybrid state, enc-dec cross
caches, sliding-window ring buffers) must say so loudly, with a message
that names the reason."""
import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, PAPER_IDS, get_smoke_config
from repro.models import (encode, forward, init_caches, init_params,
                          prepare_cross_caches)
from repro.serve.engine import Engine, ServeConfig
from repro.serve.scheduler import Scheduler

FAMS = ["stablelm_3b", "gemma2_9b", "mamba2_780m", "zamba2_7b",
        "moonshot_v1_16b", "whisper_medium", "qwen2_vl_7b", "nemotron_4_340b"]

# every small config in the registry, serving-capable or not
ALL_ARCHS = ARCH_IDS + PAPER_IDS


def _ragged_capable(cfg) -> bool:
    return (cfg.family not in ("ssm", "hybrid", "encdec")
            and cfg.sliding_window == 0 and cfg.local_global_period == 0)


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_prefill(arch, key):
    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)  # no token drops
    params = init_params(key, cfg)
    b, s = 2, 10
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    kwargs = {}
    mrope = None
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        mrope = jnp.stack([pos, pos, pos])
        kwargs["mrope_positions"] = mrope
    eo = None
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model))
        eo = encode(params, cfg, frames)
        kwargs["encoder_out"] = eo
    full, _, _ = forward(params, cfg, tokens, **kwargs)

    caches = init_caches(cfg, b, max_len=16)
    if cfg.family == "encdec":
        caches = prepare_cross_caches(params, cfg, eo, caches)
    outs = []
    for t in range(s):
        kw = {}
        if mrope is not None:
            kw["mrope_positions"] = mrope[:, :, t:t + 1]
        lg, caches, _ = forward(params, cfg, tokens[:, t:t + 1],
                                caches=caches, **kw)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(dec - full))) < 5e-4 * float(
        jnp.max(jnp.abs(full)) + 1)


# ---------------------------------------------------------------------------
# Cross-config engine matrix: scan vs step × contiguous vs paged
# ---------------------------------------------------------------------------

def _matrix_setup(arch, key):
    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        # no token drops: keeps the test about cache layouts, not routing
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    cfg = dataclasses.replace(cfg, remat=False)
    params = init_params(key, cfg)
    # stable per-arch seed (hash() is randomized per process)
    rng = np.random.default_rng(zlib.crc32(arch.encode()))
    lens = rng.integers(1, 7, size=3).astype(np.int32)
    padded = np.zeros((3, 6), np.int32)
    for i, L in enumerate(lens):
        padded[i, :L] = rng.integers(0, cfg.vocab_size, L)
    return cfg, params, lens, padded


@pytest.mark.parametrize("arch",
                         [a for a in ALL_ARCHS
                          if _ragged_capable(get_smoke_config(a))])
def test_engine_matrix_loops_and_layouts_agree(arch, key):
    """For every serving-capable registry config, all four engine variants
    (scan/step decode loop × contiguous/paged KV) generate identical
    tokens from the same ragged batch."""
    cfg, params, lens, padded = _matrix_setup(arch, key)
    outs = {}
    for loop in ("scan", "step"):
        for layout in ("contiguous", "paged"):
            eng = Engine(params, cfg,
                         ServeConfig(max_len=16, decode_loop=loop,
                                     kv_layout=layout, block_size=4))
            outs[(loop, layout)] = np.asarray(
                eng.generate(jnp.asarray(padded), 5,
                             prompt_lens=jnp.asarray(lens)))
    base = outs[("scan", "contiguous")]
    assert base.shape == (3, 5)
    for combo, out in outs.items():
        assert np.array_equal(out, base), (arch, combo)


@pytest.mark.parametrize("arch",
                         [a for a in ALL_ARCHS
                          if not _ragged_capable(get_smoke_config(a))])
def test_engine_matrix_unsupported_raise_actionably(arch, key):
    """SSM/hybrid/enc-dec/sliding-window configs must refuse ragged and
    paged serving with a message that names the reason — not crash deep in
    a scatter with a shape error."""
    cfg = get_smoke_config(arch)
    params = init_params(key, cfg)
    prompts = jnp.zeros((2, 4), jnp.int32)
    lens = jnp.asarray([2, 4], jnp.int32)
    reason = ("family" if cfg.family in ("ssm", "hybrid", "encdec")
              else "sliding-window")
    # ragged generate on the contiguous engine
    eng = Engine(params, cfg, ServeConfig(max_len=16))
    with pytest.raises(NotImplementedError, match=reason):
        eng.generate(prompts, 2, prompt_lens=lens)
    # paged generate (with or without prompt_lens)
    paged = Engine(params, cfg, ServeConfig(max_len=16, kv_layout="paged",
                                            block_size=4))
    with pytest.raises(NotImplementedError, match=reason):
        paged.generate(prompts, 2)
    # the continuous-batching scheduler refuses at construction
    with pytest.raises(NotImplementedError, match=reason):
        Scheduler(eng)
    # quantized KV is gated the same way (contiguous and paged)
    with pytest.raises(NotImplementedError, match=reason):
        Engine(params, cfg, ServeConfig(max_len=16,
                                        kv_dtype="int8")).generate(prompts, 2)


def test_chunked_prefill_matches(key):
    """Prefill in two chunks == prefill in one (chunked-prefill serving)."""
    cfg = get_smoke_config("stablelm_3b")
    params = init_params(key, cfg)
    b, s = 2, 12
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full, _, _ = forward(params, cfg, tokens)

    caches = init_caches(cfg, b, max_len=16)
    lg1, caches, _ = forward(params, cfg, tokens[:, :7], caches=caches)
    lg2, caches, _ = forward(params, cfg, tokens[:, 7:], caches=caches)
    got = jnp.concatenate([lg1, lg2], axis=1)
    assert float(jnp.max(jnp.abs(got - full))) < 5e-4 * float(
        jnp.max(jnp.abs(full)) + 1)


@pytest.mark.slow
def test_sliding_window_ring_buffer(key):
    """Ring-buffer decode == full-cache decode for a windowed layer."""
    cfg = get_smoke_config("gemma2_9b")
    params = init_params(key, cfg)
    b, s = 1, 24
    win = cfg.sliding_window
    assert win < s or win == 64  # smoke window is 64 > s → widen seq
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full, _, _ = forward(params, cfg, tokens)
    caches = init_caches(cfg, b, max_len=s)  # ring for local layers
    outs = []
    for t in range(s):
        lg, caches, _ = forward(params, cfg, tokens[:, t:t + 1], caches=caches)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(dec - full))) < 5e-4 * float(
        jnp.max(jnp.abs(full)) + 1)
