import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import CorpusConfig, SyntheticCorpus


def test_deterministic_by_step():
    c = SyntheticCorpus(CorpusConfig(vocab_size=128))
    a = c.sample(jnp.asarray(5), 4, 16)
    b = c.sample(jnp.asarray(5), 4, 16)
    assert jnp.all(a == b)
    assert not jnp.all(a == c.sample(jnp.asarray(6), 4, 16))


def test_learnable_structure():
    """Bigram structure exists: successor entropy ≪ uniform."""
    c = SyntheticCorpus(CorpusConfig(vocab_size=128))
    toks = np.asarray(c.sample(jnp.asarray(0), 16, 256)).reshape(-1)
    # count empirical successors of the most common token
    tok = np.bincount(toks).argmax()
    succ = toks[1:][toks[:-1] == tok]
    if len(succ) > 10:
        uniq = len(np.unique(succ))
        assert uniq <= c.cfg.branching


def test_calibration_disjoint_from_training():
    c = SyntheticCorpus(CorpusConfig(vocab_size=128))
    cal = list(c.calibration_batches(2, 4, 16))
    train0 = c.sample(jnp.asarray(0), 4, 16)
    assert not jnp.all(cal[0] == train0)


def test_entropy_floor_positive():
    c = SyntheticCorpus(CorpusConfig(vocab_size=128))
    assert 1.0 < c.entropy_floor() < 128


def test_pipeline_prefetch_and_resume():
    from repro.data.pipeline import DataPipeline
    c = SyntheticCorpus(CorpusConfig(vocab_size=64))
    p = DataPipeline(c, batch=4, seq=8, prefetch=2,
                     process_index=0, process_count=1)
    run1 = {s: b for s, b in p.iterate(0, 5)}
    # resume from step 3 reproduces identical batches
    run2 = {s: b for s, b in p.iterate(3, 2)}
    for s in (3, 4):
        assert jnp.all(run1[s] == run2[s])


def test_pipeline_host_slicing():
    from repro.data.pipeline import DataPipeline
    c = SyntheticCorpus(CorpusConfig(vocab_size=64))
    full = DataPipeline(c, batch=8, seq=8, process_index=0, process_count=1)
    h0 = DataPipeline(c, batch=8, seq=8, process_index=0, process_count=2)
    h1 = DataPipeline(c, batch=8, seq=8, process_index=1, process_count=2)
    fb = full.batch_at(7)
    assert jnp.all(h0.batch_at(7) == fb[:4])
    assert jnp.all(h1.batch_at(7) == fb[4:])
