"""Pallas kernels vs pure-jnp oracles: shape/dtype/rank sweeps (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizers import W4, pack_int4, quantize_weight
from repro.kernels import (act_quant, flash_attention, tuning, w4a8_fused,
                           w4a8_gemm)
from repro.kernels import ref as kref
from repro.kernels import ops


def _quant_setup(rng, m, k, n, r, dtype=np.float32):
    x = jnp.asarray(rng.normal(size=(m, k)).astype(dtype))
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    codes, sw = quantize_weight(w, W4)
    qw = pack_int4(codes).T
    mdiag = jnp.asarray(rng.uniform(0.5, 2.0, size=(k,)).astype(np.float32))
    lb = jnp.asarray(rng.normal(size=(k, r)).astype(np.float32) * 0.02)
    la = jnp.asarray(rng.normal(size=(r, n)).astype(np.float32) * 0.02)
    return x, qw, sw[:, 0], mdiag, lb, la


def _exact_gemm_oracle(xq, sx, qw, sw, xlr, la):
    """Exact-integer oracle for the GEMM kernel given ITS inputs (the e2e
    ref path quantizes independently; 1-ulp scale ties would flip codes)."""
    from repro.core.quantizers import unpack_int4
    wc = unpack_int4(qw.T).T
    acc = np.asarray(xq, np.int64) @ np.asarray(wc, np.int64)
    return (acc * np.asarray(sx) * np.asarray(sw)[None, :]
            + np.asarray(xlr) @ np.asarray(la))


@pytest.mark.parametrize("m,k,n,r", [
    (8, 128, 128, 8), (64, 256, 128, 16), (130, 512, 384, 32),
    (256, 1024, 256, 64), (32, 384, 640, 8),
])
def test_w4a8_gemm_shapes(rng, m, k, n, r):
    x, qw, sw, mdiag, lb, la = _quant_setup(rng, m, k, n, r)
    xq, sx, xlr = act_quant(x, mdiag, lb)
    y_ref = _exact_gemm_oracle(xq, sx, qw, sw, xlr, la)
    y = w4a8_gemm(xq, sx, qw, sw, xlr, la)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-3)


def test_w4a8_end_to_end_close(rng):
    """Kernel pipeline vs the independent e2e ref: close up to rounding-tie
    flips (bounded by one code step per element)."""
    x, qw, sw, mdiag, lb, la = _quant_setup(rng, 64, 512, 256, 16)
    y_ref = kref.w4a8_linear_ref(x, qw, sw, mdiag, lb, la)
    xq, sx, xlr = act_quant(x, mdiag, lb)
    y = w4a8_gemm(xq, sx, qw, sw, xlr, la)
    denom = np.abs(np.asarray(y_ref)).max()
    assert np.abs(np.asarray(y) - np.asarray(y_ref)).max() / denom < 2e-2


@pytest.mark.parametrize("bm,bn,bk", [(64, 64, 128), (128, 128, 256),
                                      (256, 128, 512)])
def test_w4a8_gemm_block_shapes(rng, bm, bn, bk):
    x, qw, sw, mdiag, lb, la = _quant_setup(rng, 200, 512, 256, 16)
    xq, sx, xlr = act_quant(x, mdiag, lb)
    y_ref = _exact_gemm_oracle(xq, sx, qw, sw, xlr, la)
    y = w4a8_gemm(xq, sx, qw, sw, xlr, la, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("m", [1, 4, 8])
@pytest.mark.parametrize("r", [3, 7, 16, 19])
def test_w4a8_fused_decode_shapes(rng, m, r):
    """Fused decode kernel == e2e reference across decode m and odd ranks."""
    x, qw, sw, mdiag, lb, la = _quant_setup(rng, m, 256, 384, r)
    y_ref = kref.w4a8_linear_ref(x, qw, sw, mdiag, lb, la)
    y = w4a8_fused(x, mdiag, qw, sw, lb, la)
    denom = float(jnp.max(jnp.abs(y_ref)))
    assert float(jnp.max(jnp.abs(y - y_ref))) / denom < 1e-4


@pytest.mark.parametrize("bn", [128, 256, 512])
def test_w4a8_fused_block_sizes(rng, bn):
    x, qw, sw, mdiag, lb, la = _quant_setup(rng, 4, 512, 640, 16)
    y_ref = kref.w4a8_linear_ref(x, qw, sw, mdiag, lb, la)
    y = w4a8_fused(x, mdiag, qw, sw, lb, la, bn=bn)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


def test_w4a8_fused_bf16_activations(rng):
    """bf16 activations: fused pass == two-kernel pipeline on the SAME
    input (vs f32 the quant codes legitimately flip with bf16 rounding)."""
    x, qw, sw, mdiag, lb, la = _quant_setup(rng, 2, 256, 128, 8)
    xbf = x.astype(jnp.bfloat16)
    y_fused = w4a8_fused(xbf, mdiag, qw, sw, lb, la)
    xq, sx, xlr = act_quant(xbf, mdiag, lb)
    y_pipe = w4a8_gemm(xq, sx, qw, sw, xlr, la)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_pipe),
                               rtol=1e-4, atol=1e-3)


def test_fused_decode_routing(rng):
    """ops routes small-m to the fused kernel; fused_decode=False pins the
    tiled pipeline; both agree with the reference."""
    from repro.runtime import RuntimeConfig
    x, qw, sw, mdiag, lb, la = _quant_setup(rng, 2, 256, 128, 16)
    assert tuning.use_fused_decode(2, 256, 128, 16)
    assert not tuning.use_fused_decode(64, 256, 128, 16)   # m over decode cap
    y_ref = kref.w4a8_linear_ref(x, qw, sw, mdiag, lb, la)
    for fused in (True, False):
        y = ops.w4a8_linear(x, qw, sw, mdiag, lb, la,
                            rt=RuntimeConfig(use_pallas=True,
                                             fused_decode=fused))
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-3, err_msg=str(fused))


def test_tuning_blocks_fit_budget():
    """Selected BlockSpecs always respect the VMEM budget model."""
    for (m, k, n, r) in [(1, 4096, 11008, 64), (8, 2048, 8192, 64),
                         (256, 4096, 4096, 64), (512, 2048, 8192, 128)]:
        bm, bn, bk = tuning.select_gemm_blocks(m, k, n, r)
        assert tuning.vmem_bytes(bm, bn, bk, r) <= tuning.VMEM_BUDGET
        if tuning.use_fused_decode(m, k, n, r):
            bn_f = tuning.fused_bn(m, k, n, r)
            assert tuning.fused_vmem_bytes(m, k, bn_f, r) \
                <= tuning.VMEM_BUDGET


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_act_quant_dtypes(rng, dtype):
    x = jnp.asarray(rng.normal(size=(48, 256)).astype(np.float32)).astype(dtype)
    mdiag = jnp.asarray(rng.uniform(0.5, 2.0, size=(256,)).astype(np.float32))
    lb = jnp.asarray(rng.normal(size=(256, 16)).astype(np.float32) * 0.02)
    xq, sx, xlr = act_quant(x, mdiag, lb)
    xq_r, sx_r = kref.act_quant_ref(x, mdiag)
    assert int(jnp.sum(jnp.abs(xq.astype(jnp.int32) - xq_r.astype(jnp.int32)) > 1)) == 0
    np.testing.assert_allclose(np.asarray(sx), np.asarray(sx_r), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(xlr),
        np.asarray((x.astype(jnp.float32) / mdiag[None]) @ lb),
        rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("sq,skv,h,hkv,d,causal,window,cap", [
    (128, 128, 4, 4, 64, True, 0, 0.0),
    (200, 200, 8, 2, 64, True, 0, 0.0),
    (256, 256, 4, 1, 128, True, 64, 0.0),
    (64, 64, 2, 2, 256, False, 0, 0.0),
    (128, 128, 4, 2, 64, True, 0, 50.0),
    (100, 100, 4, 4, 32, True, 32, 30.0),
])
def test_flash_attention_sweep(rng, sq, skv, h, hkv, d, causal, window, cap):
    b = 2
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, skv, hkv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, skv, hkv, d)).astype(np.float32))
    o = flash_attention(q, k, v, causal=causal, window=window, logit_cap=cap)
    o_ref = kref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                     logit_cap=cap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_bf16(rng):
    b, s, h, d = 2, 128, 4, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32)).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32)).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32)).astype(jnp.bfloat16)
    o = flash_attention(q, k, v)
    o_ref = kref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_ops_dispatch_consistency(rng):
    """pallas path == XLA fallback through the public ops API."""
    from repro.runtime import RuntimeConfig
    x, qw, sw, mdiag, lb, la = _quant_setup(rng, 64, 256, 128, 16)
    y_xla = ops.w4a8_linear(x, qw, sw, mdiag, lb, la,
                            rt=RuntimeConfig(use_pallas=False))
    y_pl = ops.w4a8_linear(x, qw, sw, mdiag, lb, la,
                           rt=RuntimeConfig(use_pallas=True))
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_xla),
                               rtol=1e-4, atol=1e-3)


def test_ops_rank_zero_pallas(rng):
    from repro.runtime import RuntimeConfig
    x, qw, sw, mdiag, _, _ = _quant_setup(rng, 32, 128, 64, 8)
    lb = jnp.zeros((128, 0), jnp.float32)
    la = jnp.zeros((0, 64), jnp.float32)
    y = ops.w4a8_linear(x, qw, sw, mdiag, lb, la,
                        rt=RuntimeConfig(use_pallas=True))
    y_ref = kref.w4a8_linear_ref(x, qw, sw, mdiag, lb, la)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


def test_weight_only_a16_path(rng):
    x, qw, sw, mdiag, lb, la = _quant_setup(rng, 16, 128, 64, 8)
    y = ops.w4a8_linear(x, qw, sw, mdiag, lb, la, a_bits=16)
    from repro.core.quantizers import unpack_int4
    w = unpack_int4(qw.T).T.astype(jnp.float32) * sw[None, :]
    x_s = x / mdiag[None, :]
    y_ref = x_s @ w + (x_s @ lb) @ la
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5)


# ---------------------------------------------------------------------------
# Gathered adapter epilogue (multi-tenant pools)
# ---------------------------------------------------------------------------

def _adapter_setup(rng, m, k, n, p, ra):
    alb = jnp.asarray(rng.normal(size=(p, k, ra)).astype(np.float32) * 0.02)
    ala = jnp.asarray(rng.normal(size=(p, ra, n)).astype(np.float32) * 0.02)
    alb = alb.at[0].set(0.0)                  # slot 0 = pinned base adapter
    ala = ala.at[0].set(0.0)
    idx = jnp.asarray(rng.integers(0, p, size=(m,)), jnp.int32)
    return alb, ala, idx


@pytest.mark.parametrize("m,k,n,p,ra,r", [
    (8, 128, 128, 4, 8, 8), (16, 256, 192, 6, 16, 0), (5, 128, 320, 3, 8, 16),
])
def test_fused_gather_matches_batched_gather(rng, m, k, n, p, ra, r):
    """Pallas fused gather ≡ XLA batched-gather epilogue over the same
    quantized core, including rank-0 base factors and non-multiple grids."""
    from repro.kernels import w4a8_fused_gather
    x, qw, sw, mdiag, lb, la = _quant_setup(rng, m, k, n, max(r, 1))
    if r == 0:
        lb, la = jnp.zeros((k, 0), jnp.float32), jnp.zeros((0, n),
                                                           jnp.float32)
    alb, ala, idx = _adapter_setup(rng, m, k, n, p, ra)
    y = w4a8_fused_gather(x, mdiag, qw, sw, lb, la, alb, ala, idx)
    y_ref = kref.w4a8_linear_ref(x, qw, sw, mdiag, lb, la)
    y_ref = y_ref + ops.adapter_epilogue(x / mdiag[None, :], alb, ala, idx)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-3)


def test_gather_base_rows_exact_zero_delta(rng):
    """Rows routed to slot 0 must equal the adapter-free kernel bit for
    bit — the base epilogue contribution is exactly +0.0, not epsilon."""
    from repro.kernels import w4a8_fused, w4a8_fused_gather
    m, k, n, p, ra = 8, 128, 128, 4, 8
    x, qw, sw, mdiag, lb, la = _quant_setup(rng, m, k, n, 8)
    alb, ala, _ = _adapter_setup(rng, m, k, n, p, ra)
    idx = jnp.zeros((m,), jnp.int32)          # every row on the base slot
    y = w4a8_fused_gather(x, mdiag, qw, sw, lb, la, alb, ala, idx)
    y_base = w4a8_fused(x, mdiag, qw, sw, lb, la)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_base))


def test_ops_linear_routes_adapter_both_paths(rng):
    """ops.w4a8_linear(adapter=...) agrees between the Pallas path (fused
    gather at decode shapes) and the XLA batched gather, and weight-only
    a_bits=16 applies the same epilogue."""
    from repro.runtime import RuntimeConfig
    m, k, n, p, ra = 4, 128, 128, 3, 8
    x, qw, sw, mdiag, lb, la = _quant_setup(rng, m, k, n, 8)
    alb, ala, idx = _adapter_setup(rng, m, k, n, p, ra)
    adapter = (alb, ala, idx)
    y_xla = ops.w4a8_linear(x, qw, sw, mdiag, lb, la, adapter=adapter,
                            rt=RuntimeConfig(use_pallas=False))
    y_pl = ops.w4a8_linear(x, qw, sw, mdiag, lb, la, adapter=adapter,
                           rt=RuntimeConfig(use_pallas=True))
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_xla),
                               rtol=1e-4, atol=1e-3)
    y16 = ops.w4a8_linear(x, qw, sw, mdiag, lb, la, adapter=adapter,
                          a_bits=16)
    x_s = x / mdiag[None, :]
    from repro.core.quantizers import unpack_int4
    w = unpack_int4(qw.T).T.astype(jnp.float32) * sw[None, :]
    y16_ref = (x_s @ w + (x_s @ lb) @ la
               + ops.adapter_epilogue(x_s, alb, ala, idx))
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y16_ref),
                               rtol=1e-5, atol=1e-5)
