"""End-to-end system test: train → checkpoint → calibrate → ASER-quantize →
serve. The full production story on a tiny model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_smoke_config
from repro.core.metrics import perplexity
from repro.data.synthetic import CorpusConfig, SyntheticCorpus
from repro.models import forward, init_params
from repro.quant import PTQConfig, calibrate, quantize_model
from repro.runtime import RuntimeConfig
from repro.serve.engine import Engine, ServeConfig
from repro.train.loop import TrainConfig, make_train_step
from repro.train.optimizer import OptConfig, init_opt_state
import pytest


@pytest.mark.slow
def test_full_system(tmp_path):
    cfg = get_smoke_config("llama3_8b").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=128, dtype="float32")
    cfg = dataclasses.replace(cfg, remat=False)
    corpus = SyntheticCorpus(CorpusConfig(vocab_size=cfg.vocab_size))

    # 1. train with checkpoints
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(
        cfg, TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=5,
                                       total_steps=60))))
    mgr = CheckpointManager(str(tmp_path), keep=1)
    first_loss = last_loss = None
    for i in range(60):
        batch = {"tokens": corpus.sample(jnp.asarray(i), 8, 33)}
        params, opt, m = step(params, opt, batch)
        if first_loss is None:
            first_loss = float(m["loss"])
        last_loss = float(m["loss"])
    assert last_loss < first_loss - 0.3
    mgr.save(60, {"params": params})

    # 2. restore (simulated restart)
    _, st = mgr.restore_latest({"params": params})
    params = st["params"]

    # 3. calibrate + ASER quantize (paper pipeline)
    tape = calibrate(params, cfg, corpus.calibration_batches(2, 4, 32))
    qp = quantize_model(params, tape, PTQConfig(method="aser_as", rank=8,
                                                outlier_f=8))

    # 4. quantized PPL stays close to fp
    toks = corpus.sample(jnp.asarray(9999), 8, 64)
    lg_fp, _, _ = forward(params, cfg, toks)
    lg_q, _, _ = forward(qp, cfg, toks)
    ppl_fp = float(perplexity(lg_fp[:, :-1], toks[:, 1:]))
    ppl_q = float(perplexity(lg_q[:, :-1], toks[:, 1:]))
    assert ppl_q < ppl_fp * 1.15, (ppl_fp, ppl_q)

    # 5. serve the quantized model (greedy decode, deterministic)
    eng = Engine(qp, cfg, ServeConfig(max_len=32))
    prompts = corpus.sample(jnp.asarray(777), 2, 8)
    out1 = eng.generate(prompts, n_steps=6)
    out2 = eng.generate(prompts, n_steps=6)
    assert out1.shape == (2, 6) and bool(jnp.all(out1 == out2))

    # 6. pallas kernel path agrees on the generation (per-engine runtime,
    #    no process-global toggles)
    out_pl = Engine(qp, cfg, ServeConfig(max_len=32),
                    rt=RuntimeConfig(use_pallas=True)).generate(
        prompts, n_steps=6)
    assert float(jnp.mean((out_pl == out1).astype(jnp.float32))) > 0.8
