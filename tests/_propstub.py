"""Minimal stand-in for ``hypothesis`` when it isn't installed.

The real library is preferred (it shrinks failures and explores the space);
this shim keeps the property tests *collectable and meaningful* without it by
expanding ``@given`` into a ``pytest.mark.parametrize`` over deterministic
representative samples of each strategy (bounds, midpoint, and a couple of
interior points). Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                     # pragma: no cover - env dependent
        from _propstub import given, settings, st
"""
from __future__ import annotations

import inspect
import itertools

import pytest


class _Strategy:
    def __init__(self, examples):
        self.examples = list(examples)


class st:
    """Subset of ``hypothesis.strategies`` used by this test suite."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        span = max_value - min_value
        pts = {min_value, max_value, min_value + span // 2,
               min_value + span // 3, min_value + (2 * span) // 3}
        return _Strategy(sorted(pts))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        mid = (min_value + max_value) / 2
        return _Strategy([min_value, mid, max_value])


def settings(**_kw):
    """All hypothesis settings (max_examples, deadline, ...) are no-ops."""
    def deco(fn):
        return fn
    return deco


def given(*strategies: _Strategy):
    """Expand strategy samples into parametrized cases.

    Mirrors hypothesis' convention that positional strategies fill the test
    function's *last* parameters (leading ones stay pytest fixtures).
    """
    def deco(fn):
        params = list(inspect.signature(fn).parameters)
        names = params[len(params) - len(strategies):]
        cases = list(itertools.product(*[s.examples for s in strategies]))
        if len(names) == 1:
            # parametrize over one name takes scalars, not 1-tuples
            cases = [c[0] for c in cases]
        return pytest.mark.parametrize(",".join(names), cases)(fn)
    return deco
