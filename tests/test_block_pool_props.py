"""Property tests for the host-side paged-cache allocator invariants.

`serve/paged_cache.py::BlockPool` is the one piece of serving state the
device never checks — a refcount bug here silently hands one request's
pages to another. These tests drive randomized (but fixed-seed,
deterministic) op sequences against a shadow model and pin the invariants:

* refcounts never go negative, and every block is in exactly one of the
  three states (live / cached / free);
* LRU eviction never reclaims a live (incref'd) page;
* ``cow()`` leaves the source's refcount intact and returns a private id;
* ``alloc`` after exhaustion fails cleanly (returns None, state unchanged).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # fallback: deterministic samples, see _propstub
    from _propstub import given, settings, st

from repro.serve.paged_cache import BlockPool, block_hashes


def _invariants(pool: BlockPool):
    """The global consistency every op sequence must preserve."""
    assert (pool.ref >= 0).all(), "negative refcount"
    free = set(pool._free)
    cached = {bid for bid in pool._by_hash.values() if pool.ref[bid] == 0}
    live = {int(b) for b in np.flatnonzero(pool.ref > 0)}
    # free ∩ (cached ∪ live) = ∅; all ids accounted for or idle-but-indexed
    assert not (free & live), "free list holds a live block"
    assert not (free & cached), "free list holds a cached (indexed) block"
    assert pool.available() == len(free) + len(cached)
    assert pool.live() == len(live)
    # the hash index is a bijection over its blocks
    assert len(pool._by_hash) == len(pool._hash_of)
    for h, bid in pool._by_hash.items():
        assert pool._hash_of[bid] == h


def _random_ops(pool: BlockPool, rng: np.random.Generator, n_ops: int):
    """Random alloc/free/incref/match/register/cow/evict traffic."""
    held = []                 # (bid, times_held) we still owe frees for
    next_tok = 0
    for _ in range(n_ops):
        op = rng.integers(0, 6)
        if op == 0:           # alloc a few
            n = int(rng.integers(1, 3))
            got = pool.alloc(n)
            if got is not None:
                assert len(got) == n
                assert all(pool.ref[b] == 1 for b in got)
                held.extend(got)
        elif op == 1 and held:  # free one we hold
            bid = held.pop(int(rng.integers(0, len(held))))
            pool.free([bid])
        elif op == 2 and held:  # incref one we hold (second holder)
            bid = held[int(rng.integers(0, len(held)))]
            pool.incref([bid])
            held.append(bid)
        elif op == 3 and held:  # register a prefix over a held block
            bid = held[int(rng.integers(0, len(held)))]
            toks = np.full((pool.block_size,), next_tok, np.int32)
            next_tok += 1
            pool.register_prefix(toks, [bid])
        elif op == 4:           # match some previous prefix (takes refs)
            toks = np.full((pool.block_size,),
                           int(rng.integers(0, max(next_tok, 1))), np.int32)
            ids, _ = pool.match_prefix(toks)
            held.extend(ids)
        elif op == 5 and held:  # cow a held block
            bid = held[int(rng.integers(0, len(held)))]
            ref_before = int(pool.ref[bid])
            dst = pool.cow(bid)
            assert int(pool.ref[bid]) == ref_before, \
                "cow changed the source refcount"
            if dst is not None and dst != bid:
                assert pool.ref[dst] == 1
                held.append(dst)
        _invariants(pool)
    return held


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_pool_invariants_under_random_traffic(seed):
    rng = np.random.default_rng(seed)
    pool = BlockPool(int(rng.integers(2, 12)), int(rng.integers(1, 6)))
    held = _random_ops(pool, rng, 60)
    # drain: every held reference frees exactly once, pool returns to empty
    pool.free(held)
    _invariants(pool)
    assert pool.live() == 0
    assert pool.available() == pool.num_blocks


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_eviction_never_reclaims_live_pages(seed):
    rng = np.random.default_rng(seed)
    pool = BlockPool(6, 4)
    live = pool.alloc(int(rng.integers(1, 4)))
    # index the live blocks AND retire-then-cache some others
    for i, bid in enumerate(live):
        pool.register_prefix(np.full((4,), 100 + i, np.int32), [bid])
    cached = pool.alloc(6 - len(live))
    for i, bid in enumerate(cached):
        pool.register_prefix(np.full((4,), 200 + i, np.int32), [bid])
    pool.free(cached)          # now evictable; `live` still held
    _invariants(pool)
    # exhaust the pool: every alloc must come from the cached set only
    got = pool.alloc(pool.available())
    assert got is not None and set(got) == set(cached)
    assert all(pool.ref[b] == 1 for b in live), "eviction touched live page"
    # the evicted blocks' index entries are gone, the live ones' remain
    for i in range(len(cached)):
        ids, n = pool.match_prefix(np.full((4,), 200 + i, np.int32))
        assert ids == [] and n == 0
    ids, n = pool.match_prefix(np.full((4,), 100, np.int32))
    assert ids == [live[0]] and n == 4
    pool.free(ids)


def test_alloc_after_exhaustion_fails_cleanly():
    pool = BlockPool(3, 2)
    got = pool.alloc(3)
    assert got is not None
    before = (pool.ref.copy(), list(pool._free), dict(pool._by_hash),
              pool.evictions)
    assert pool.alloc(1) is None          # exhausted: clean failure
    assert pool.alloc(0) == []            # zero is always satisfiable
    after = (pool.ref, list(pool._free), dict(pool._by_hash), pool.evictions)
    assert (before[0] == after[0]).all() and before[1:] == after[1:], \
        "failed alloc mutated pool state"
    with pytest.raises(ValueError, match=r"alloc\(-1\)"):
        pool.alloc(-1)
    pool.free(got)
    assert pool.alloc(3) is not None      # recovers fully


def test_cow_preserves_contents_identity_and_source_ref():
    """Pool-level COW contract: the returned id is private, the source's
    refcount is untouched (the *caller* later drops its reference), and a
    private unindexed block is returned as-is (contents trivially
    preserved — the device copy is only issued when the id changes)."""
    pool = BlockPool(4, 4)
    toks = np.arange(4, dtype=np.int32)
    (a,) = pool.alloc(1)
    assert pool.cow(a) == a               # sole holder, unindexed: in place
    pool.register_prefix(toks, [a])
    ids, _ = pool.match_prefix(toks)      # second holder
    assert ids == [a] and pool.ref[a] == 2
    dst = pool.cow(a)
    assert dst is not None and dst != a and pool.ref[dst] == 1
    assert pool.ref[a] == 2, "cow dropped the source reference itself"
    # caller then frees its ref on the source, exactly once
    pool.free([a, dst])
    assert pool.ref[a] == 1
    # exhaustion: cow degrades to None, source still intact
    rest = pool.alloc(pool.available())
    ids, _ = pool.match_prefix(toks)
    assert pool.cow(a) is None and pool.ref[a] == 2
    pool.free(ids)
    pool.free([a] + rest)


def test_reregistered_block_with_duplicate_content_drops_stale_alias():
    """A rewritten block whose new content is already indexed via another
    block must lose its stale hash alias — otherwise a later match through
    the stale hash serves the rewritten (wrong) KV content."""
    pool = BlockPool(4, 4)
    old = np.arange(4, dtype=np.int32)
    dup = np.full((4,), 9, np.int32)
    (a,) = pool.alloc(1)
    (b,) = pool.alloc(1)
    pool.register_prefix(old, [a])         # a holds `old`
    pool.register_prefix(dup, [b])         # b holds `dup`
    # a's holder rewrites it with `dup` content and re-registers
    pool.register_prefix(dup, [a])
    _invariants(pool)
    ids, n = pool.match_prefix(old)        # stale alias must be gone
    assert ids == [] and n == 0
    ids, _ = pool.match_prefix(dup)
    assert ids == [b]
    pool.free(ids)
    pool.free([a, b])
    # an unreferenced block losing its only index entry returns to the
    # free list instead of being stranded
    pool2 = BlockPool(2, 4)
    (x,) = pool2.alloc(1)
    (y,) = pool2.alloc(1)
    pool2.register_prefix(old, [x])
    pool2.register_prefix(dup, [y])
    pool2.free([x])                        # x now cached (ref 0, indexed)
    pool2.register_prefix(dup, [x])        # stale alias drop ⇒ x unindexed
    _invariants(pool2)
    assert pool2.available() == 1          # x is free again, not stranded
    pool2.free([y])


def test_double_free_and_free_incref_guards():
    pool = BlockPool(2, 2)
    (a,) = pool.alloc(1)
    pool.free([a])
    with pytest.raises(ValueError, match="double free"):
        pool.free([a])
    with pytest.raises(ValueError, match="incref of free block"):
        pool.incref([a])
    _invariants(pool)
